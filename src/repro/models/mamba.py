"""Mamba (S6) selective-state-space block, TPU-adapted.

The CUDA reference fuses a sequential selective scan into one kernel; the
TPU-native adaptation uses a *chunked associative scan*: within a chunk the
linear recurrence h_t = a_t * h_{t-1} + b_t is evaluated with
jax.lax.associative_scan (log-depth, MXU-friendly), chunks are chained
sequentially with the boundary state, and each chunk body is rematerialized
in the backward pass so peak memory stays O(chunk * d_inner * state) instead
of O(seq * d_inner * state).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .layers import dense_init

__all__ = ["init_mamba_params", "mamba_forward", "init_mamba_cache",
           "mamba_decode"]


def init_mamba_params(key, d_model: int, *, expand: int = 2, state: int = 16,
                      conv: int = 4, dtype=jnp.float32):
    di = expand * d_model
    dt_rank = max(1, math.ceil(d_model / 16))
    ks = jax.random.split(key, 7)
    a = jnp.tile(jnp.arange(1, state + 1, dtype=jnp.float32)[None], (di, 1))
    return {
        "in_proj": dense_init(ks[0], d_model, 2 * di, dtype),
        "conv_w": (jax.random.normal(ks[1], (conv, di)) / math.sqrt(conv)
                   ).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": dense_init(ks[2], di, dt_rank + 2 * state, dtype),
        "dt_proj": dense_init(ks[3], dt_rank, di, dtype),
        "dt_bias": (jnp.log(jnp.expm1(
            jnp.clip(jnp.exp(jax.random.uniform(ks[4], (di,))
                             * (math.log(0.1) - math.log(0.001))
                             + math.log(0.001)), 1e-4, None)))).astype(jnp.float32),
        "a_log": jnp.log(a),                       # (di, state) fp32
        "d": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[5], di, d_model, dtype),
    }


def _ssm_scan_chunked(a, b, h0, chunk: int):
    """h_t = a_t * h_{t-1} + b_t over axis 1.  a, b: (B, S, di, N); h0: (B, di, N).
    Returns (hs (B, S, di, N), h_last)."""
    B, S, di, N = a.shape
    c = min(chunk, S)
    assert S % c == 0
    nc = S // c
    ac = a.reshape(B, nc, c, di, N).transpose(1, 0, 2, 3, 4)
    bc = b.reshape(B, nc, c, di, N).transpose(1, 0, 2, 3, 4)

    @jax.checkpoint
    def one_chunk(h, ab):
        a_, b_ = ab
        # fold the carry state into the first step
        b_ = b_.at[:, 0].add(a_[:, 0] * h)

        def combine(x, y):
            ax, bx = x
            ay, by = y
            return ax * ay, ay * bx + by
        _, hs = jax.lax.associative_scan(combine, (a_, b_), axis=1)
        return hs[:, -1], hs

    h_last, hs = jax.lax.scan(one_chunk, h0, (ac, bc))
    hs = hs.transpose(1, 0, 2, 3, 4).reshape(B, S, di, N)
    return hs, h_last


def mamba_forward(params, x, *, expand: int = 2, state: int = 16,
                  conv: int = 4, scan_chunk: int = 64, h0=None,
                  return_state: bool = False):
    """x: (B, S, d) -> (B, S, d)."""
    B, S, d = x.shape
    di = expand * d
    dt_rank = params["dt_proj"].shape[0]

    xz = x @ params["in_proj"]
    xin, z = jnp.split(xz, 2, axis=-1)                     # (B, S, di) each

    # causal depthwise conv1d
    pad = jnp.zeros((B, conv - 1, di), xin.dtype)
    xp = jnp.concatenate([pad, xin], axis=1)
    xc = sum(xp[:, i:i + S] * params["conv_w"][i] for i in range(conv))
    xc = jax.nn.silu(xc + params["conv_b"])

    proj = xc @ params["x_proj"]                           # (B, S, rank+2N)
    dt_in, bmat, cmat = jnp.split(proj, [dt_rank, dt_rank + state], axis=-1)
    dt = jax.nn.softplus(dt_in @ params["dt_proj"]
                         + params["dt_bias"]).astype(jnp.float32)  # (B,S,di)
    a = -jnp.exp(params["a_log"])                          # (di, N)
    abar = jnp.exp(dt[..., None] * a)                      # (B,S,di,N)
    bbar = (dt[..., None] * bmat[:, :, None, :].astype(jnp.float32)
            * xc[..., None].astype(jnp.float32))           # (B,S,di,N)

    if h0 is None:
        h0 = jnp.zeros((B, di, state), jnp.float32)
    hs, h_last = _ssm_scan_chunked(abar, bbar, h0, scan_chunk)

    y = jnp.einsum("bsdn,bsn->bsd", hs, cmat.astype(jnp.float32))
    y = y + params["d"] * xc.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = y @ params["out_proj"]
    if return_state:
        return out, h_last
    return out


# ---------------------------------------------------------------------------
# decode: O(1) recurrent state
# ---------------------------------------------------------------------------

def init_mamba_cache(batch: int, d_model: int, *, expand: int = 2,
                     state: int = 16, conv: int = 4, dtype=jnp.float32):
    di = expand * d_model
    return {"h": jnp.zeros((batch, di, state), jnp.float32),
            "conv": jnp.zeros((batch, conv - 1, di), dtype)}


def mamba_decode(params, cache, x, *, expand: int = 2, state: int = 16,
                 conv: int = 4):
    """x: (B, 1, d) -> (out (B, 1, d), new_cache)."""
    B, _, d = x.shape
    dt_rank = params["dt_proj"].shape[0]

    xz = x @ params["in_proj"]
    xin, z = jnp.split(xz, 2, axis=-1)                     # (B, 1, di)

    hist = jnp.concatenate([cache["conv"], xin.astype(cache["conv"].dtype)],
                           axis=1)                         # (B, conv, di)
    xc = jnp.einsum("bcd,cd->bd", hist, params["conv_w"])[:, None]
    xc = jax.nn.silu(xc + params["conv_b"])

    proj = xc @ params["x_proj"]
    dt_in, bmat, cmat = jnp.split(proj, [dt_rank, dt_rank + state], axis=-1)
    dt = jax.nn.softplus(dt_in @ params["dt_proj"] + params["dt_bias"]
                         ).astype(jnp.float32)
    a = -jnp.exp(params["a_log"])
    abar = jnp.exp(dt[:, 0, :, None] * a)                  # (B, di, N)
    bbar = (dt[:, 0, :, None] * bmat[:, 0, None, :].astype(jnp.float32)
            * xc[:, 0, :, None].astype(jnp.float32))
    h = abar * cache["h"] + bbar
    y = jnp.einsum("bdn,bn->bd", h, cmat[:, 0].astype(jnp.float32))
    y = y + params["d"] * xc[:, 0].astype(jnp.float32)
    y = (y * jax.nn.silu(z[:, 0].astype(jnp.float32))).astype(x.dtype)
    out = (y @ params["out_proj"])[:, None]
    return out, {"h": h, "conv": hist[:, 1:]}
