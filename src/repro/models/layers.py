"""Shared neural building blocks (pure functions + explicit param dicts)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp



def dtype_of(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype, scale: float | None = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (scale * jax.random.normal(key, (d_in, d_out))).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype):
    return (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms / activations
# ---------------------------------------------------------------------------

def rms_norm(x, scale, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))).astype(dt)


def softcap(x, cap: float):
    """gemma2 logit soft-capping: cap * tanh(x / cap)."""
    if not cap:
        return x
    return cap * jnp.tanh(x / cap)


def swiglu(x, w1, w3, w2):
    h = jax.nn.silu(x @ w1) * (x @ w3)
    return h @ w2


# ---------------------------------------------------------------------------
# RoPE / M-RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return theta ** (-jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: broadcastable to (..., S) int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    angles = positions.astype(jnp.float32)[..., None] * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]                 # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, theta: float, sections):
    """qwen2-vl M-RoPE: positions3 (3, ..., S) = (t, h, w) ids; head_dim is
    split into `sections` (halved pair-counts) each rotated by its own id."""
    hd = x.shape[-1]
    half = hd // 2
    assert sum(sections) == half, (sections, hd)
    freqs = rope_freqs(hd, theta)                       # (half,)
    # per-frequency section selector: frequency i rotates by positions3[sec_ids[i]]
    sec_ids = jnp.repeat(jnp.arange(len(sections)), jnp.asarray(sections),
                         total_repeat_length=half)      # (half,)
    p = jnp.moveaxis(positions3, 0, -1)                 # (..., S, 3)
    pos_per_freq = p[..., sec_ids]                      # (..., S, half)
    angles = pos_per_freq.astype(jnp.float32) * freqs   # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------

def cross_entropy(logits, labels, mask=None, logical_vocab: int | None = None):
    """Masked mean token cross-entropy; ignores padded vocab tail."""
    if logical_vocab is not None and logical_vocab < logits.shape[-1]:
        neg = jnp.finfo(logits.dtype).min
        pad = jnp.full((logits.shape[-1] - logical_vocab,), neg, logits.dtype)
        logits = logits.at[..., logical_vocab:].set(pad)
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
