"""build_model(cfg) — one uniform API over every architecture family.

API (all pure functions):
  init(key)                          -> params        (single learner, no stack)
  loss_fn(params, batch)             -> scalar        (one learner's minibatch)
  apply(params, batch)               -> logits        (train/prefill forward)
  init_cache(params, batch, buf_len) -> decode cache
  decode_step(params, cache, tokens, pos) -> (logits, cache)
  train_batch_spec(global_batch, seq)     -> ShapeDtypeStruct pytree
  decode_batch_spec(global_batch, seq)    -> (cache_spec builder inputs)

Families:
  text (dense|moe|ssm|hybrid): batch = {tokens, labels, mask}
  vlm:   batch += patch_embeds (B, P, d) stub vision embeddings; text length
         is seq - P so the *total* token count matches the assigned shape.
  audio: enc-dec; batch = {frames (B, S/2, d), tokens/labels/mask (B, S/2)} —
         S/2 + S/2 = S total positions per the assigned shape.
"""
from __future__ import annotations

import math
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import encdec, transformer
from .layers import cross_entropy, dtype_of


class ModelAPI(NamedTuple):
    cfg: ModelConfig
    init: Callable
    loss_fn: Callable
    apply: Callable
    init_cache: Callable
    decode_step: Callable
    train_batch_spec: Callable
    has_decode: bool
    # paged serving decode (ISSUE 7): per-slot positions + shared page
    # pools; None/False for families without it (audio enc-dec, M-RoPE vlm)
    init_paged_cache: Any = None
    paged_decode_step: Any = None
    reset_slot: Any = None
    has_paged: bool = False


def _mrope_positions(cfg: ModelConfig, P: int, S_text: int):
    """(3, P + S_text) (t, h, w) ids: image patches on an HxW grid at t=0,
    text tokens strictly after (qwen2-vl scheme)."""
    g = max(1, int(math.sqrt(P)))
    t_img = jnp.zeros((P,), jnp.int32)
    h_img = (jnp.arange(P) // g).astype(jnp.int32)
    w_img = (jnp.arange(P) % g).astype(jnp.int32)
    base = jnp.maximum(jnp.maximum(h_img.max(), w_img.max()), 0) + 1
    t_txt = base + jnp.arange(S_text, dtype=jnp.int32)
    pos = jnp.stack([
        jnp.concatenate([t_img, t_txt]),
        jnp.concatenate([h_img, t_txt]),
        jnp.concatenate([w_img, t_txt]),
    ])
    return pos


def build_model(cfg: ModelConfig) -> ModelAPI:
    act_dt = dtype_of(cfg.compute_dtype)

    # ------------------------------------------------------------- text LM --
    if cfg.family in ("dense", "moe", "ssm", "hybrid"):
        def init(key):
            return transformer.init_params(key, cfg)

        def apply(params, batch):
            return transformer.apply(params, cfg, batch["tokens"])

        def loss_fn(params, batch):
            logits = apply(params, batch)
            return cross_entropy(logits, batch["labels"], batch.get("mask"),
                                 logical_vocab=cfg.vocab)

        def init_cache(params, batch_size, buf_len):
            return transformer.init_cache(cfg, batch_size, buf_len)

        def decode_step(params, cache, tokens, pos):
            return transformer.decode_step(params, cfg, cache, tokens, pos)

        def train_batch_spec(global_batch, seq):
            tok = jax.ShapeDtypeStruct((global_batch, seq), jnp.int32)
            return {"tokens": tok, "labels": tok,
                    "mask": jax.ShapeDtypeStruct((global_batch, seq),
                                                 jnp.float32)}

        def init_paged_cache(params, n_slots, n_pages, page_size):
            return transformer.init_paged_cache(cfg, n_slots, n_pages,
                                                page_size)

        def paged_decode_step(params, cache, tokens, positions, page_table,
                              advance=None):
            return transformer.paged_decode_step(params, cfg, cache, tokens,
                                                 positions, page_table,
                                                 advance)

        return ModelAPI(cfg=cfg, init=init, loss_fn=loss_fn, apply=apply,
                        init_cache=init_cache, decode_step=decode_step,
                        train_batch_spec=train_batch_spec, has_decode=True,
                        init_paged_cache=init_paged_cache,
                        paged_decode_step=paged_decode_step,
                        reset_slot=transformer.reset_slot, has_paged=True)

    # ---------------------------------------------------------------- VLM --
    elif cfg.family == "vlm":
        P = cfg.n_frontend_tokens

        def init(key):
            return transformer.init_params(key, cfg)

        def apply(params, batch):
            S_text = batch["tokens"].shape[1]
            pos = _mrope_positions(cfg, P, S_text)
            return transformer.apply(params, cfg, batch["tokens"],
                                     positions=pos,
                                     extra_embeds=batch["patch_embeds"])

        def loss_fn(params, batch):
            logits = apply(params, batch)[:, P:, :]
            return cross_entropy(logits, batch["labels"], batch.get("mask"),
                                 logical_vocab=cfg.vocab)

        def init_cache(params, batch_size, buf_len):
            return transformer.init_cache(cfg, batch_size, buf_len)

        def decode_step(params, cache, tokens, pos):
            return transformer.decode_step(params, cfg, cache, tokens, pos)

        def train_batch_spec(global_batch, seq):
            s_text = seq - P
            tok = jax.ShapeDtypeStruct((global_batch, s_text), jnp.int32)
            return {"tokens": tok, "labels": tok,
                    "mask": jax.ShapeDtypeStruct((global_batch, s_text),
                                                 jnp.float32),
                    "patch_embeds": jax.ShapeDtypeStruct(
                        (global_batch, P, cfg.d_model), act_dt)}

    # ------------------------------------------------------------- audio --
    elif cfg.family == "audio":
        def init(key):
            return encdec.init_params(key, cfg)

        def apply(params, batch):
            return encdec.apply(params, cfg, batch["frames"], batch["tokens"])

        def loss_fn(params, batch):
            logits = apply(params, batch)
            return cross_entropy(logits, batch["labels"], batch.get("mask"),
                                 logical_vocab=cfg.vocab)

        def init_cache(params, frames, buf_len):
            return encdec.init_cache(params, cfg, frames, buf_len)

        def decode_step(params, cache, tokens, pos):
            return encdec.decode_step(params, cfg, cache, tokens, pos)

        def train_batch_spec(global_batch, seq):
            s = seq // 2
            tok = jax.ShapeDtypeStruct((global_batch, s), jnp.int32)
            return {"frames": jax.ShapeDtypeStruct((global_batch, s,
                                                    cfg.d_model), act_dt),
                    "tokens": tok, "labels": tok,
                    "mask": jax.ShapeDtypeStruct((global_batch, s),
                                                 jnp.float32)}

    else:
        raise ValueError(cfg.family)

    return ModelAPI(cfg=cfg, init=init, loss_fn=loss_fn, apply=apply,
                    init_cache=init_cache, decode_step=decode_step,
                    train_batch_spec=train_batch_spec,
                    has_decode=True)


def make_synthetic_batch(cfg: ModelConfig, key, global_batch: int, seq: int):
    """Concrete random batch matching train_batch_spec (for smoke tests)."""
    api = build_model(cfg)
    spec = api.train_batch_spec(global_batch, seq)

    def fill(s):
        if jnp.issubdtype(s.dtype, jnp.integer):
            return jax.random.randint(key, s.shape, 0, cfg.vocab, s.dtype)
        if "mask" in str(s.shape):
            return jnp.ones(s.shape, s.dtype)
        return jax.random.normal(key, s.shape, jnp.float32).astype(s.dtype) * 0.1

    batch = {k: fill(v) for k, v in spec.items()}
    if "mask" in batch:
        batch["mask"] = jnp.ones(spec["mask"].shape, jnp.float32)
    return batch
