"""Attention: GQA projections + chunked (flash-style) jnp attention + decode.

The training/prefill path is *chunked* with an online softmax — materializing
a 32k x 32k score matrix is a non-starter on 16 GB HBM, so the pure-jnp
reference is already blocked (the Pallas kernel in repro/kernels is the
TPU-tiled version of exactly this loop and is checked against it).

Supports: causal masking, sliding windows, gemma2 attn-logit softcap, GQA
(n_kv_heads <= n_heads), MQA (n_kv_heads == 1), RoPE / M-RoPE via a caller-
supplied rope_fn.  Decode uses a rotating KV buffer of size
min(seq_len, window) so long_500k sliding-window serving is O(window) memory.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from .layers import dense_init, softcap

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------

def init_attn_params(key, d_model: int, n_heads: int, n_kv: int, head_dim: int,
                     dtype):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": dense_init(k1, d_model, n_heads * head_dim, dtype),
        "wk": dense_init(k2, d_model, n_kv * head_dim, dtype),
        "wv": dense_init(k3, d_model, n_kv * head_dim, dtype),
        "wo": dense_init(k4, n_heads * head_dim, d_model, dtype),
    }


# ---------------------------------------------------------------------------
# chunked attention core (training / prefill)
# ---------------------------------------------------------------------------

def chunked_attention(q, k, v, *, q_positions, k_positions, causal: bool = True,
                      window: int = 0, attn_softcap: float = 0.0,
                      chunk: int = 1024):
    """q: (B, Sq, H, hd); k, v: (B, Sk, KV, hd); positions: (Sq,), (Sk,).

    Returns (B, Sq, H, hd).  Blocked over both q and k with an online
    softmax; each q-block body is rematerialized in the backward pass.
    """
    B, Sq, H, hd = q.shape
    _, Sk, KV, _ = k.shape
    G = H // KV
    cq = min(chunk, Sq)
    ck = min(chunk, Sk)
    assert Sq % cq == 0 and Sk % ck == 0, (Sq, Sk, chunk)
    nq, nk = Sq // cq, Sk // ck
    scale = hd ** -0.5

    qs = q.reshape(B, nq, cq, KV, G, hd).transpose(1, 0, 2, 3, 4, 5)
    ks = k.reshape(B, nk, ck, KV, hd).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(B, nk, ck, KV, hd).transpose(1, 0, 2, 3, 4)
    qp = q_positions.reshape(nq, cq)
    kp = k_positions.reshape(nk, ck)

    @jax.checkpoint
    def one_q_block(qb, qpb):
        # qb: (B, cq, KV, G, hd); qpb: (cq,)
        m0 = jnp.full((B, KV, G, cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, cq), jnp.float32)
        a0 = jnp.zeros((B, cq, KV, G, hd), jnp.float32)

        def kv_step(carry, inp):
            m, l, acc = carry
            kb, vb, kpb = inp
            s = jnp.einsum("bqkgd,bckd->bkgqc", qb.astype(jnp.float32),
                           kb.astype(jnp.float32)) * scale
            if attn_softcap:
                s = softcap(s, attn_softcap)
            mask = jnp.ones((cq, ck), bool)
            if causal:
                mask &= qpb[:, None] >= kpb[None, :]
            if window:
                mask &= qpb[:, None] - kpb[None, :] < window
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bkgqc,bckd->bqkgd", p, vb.astype(jnp.float32))
            acc_new = acc * corr.transpose(0, 3, 1, 2)[..., None] + pv
            return (m_new, l_new, acc_new), None

        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), (ks, vs, kp))
        out = acc / jnp.maximum(l, 1e-30).transpose(0, 3, 1, 2)[..., None]
        return out  # (B, cq, KV, G, hd)

    def q_step(_, inp):
        qb, qpb = inp
        return None, one_q_block(qb, qpb)

    _, outs = jax.lax.scan(q_step, None, (qs, qp))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, H, hd)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# full attention layer forward (training / prefill)
# ---------------------------------------------------------------------------

def attn_forward(params, x, *, n_heads: int, n_kv: int, head_dim: int,
                 rope_fn: Callable, q_positions, k_positions=None,
                 window: int = 0, attn_softcap: float = 0.0, chunk: int = 1024,
                 kv_input=None, causal: bool = True, use_pallas: bool = False,
                 mask_positions=None):
    """x: (B, S, d).  kv_input: cross-attention memory (B, Sk, d) or None.

    q_positions feed the rope_fn (may be (3, S) for M-RoPE); mask_positions
    (default: q_positions) are the scalar (S,) ids used for causal/window
    masking.
    """
    B, S, _ = x.shape
    kv_src = x if kv_input is None else kv_input
    Sk = kv_src.shape[1]
    if mask_positions is None:
        mask_positions = q_positions
    k_mask_positions = mask_positions if kv_input is None else jnp.arange(Sk)

    q = (x @ params["wq"]).reshape(B, S, n_heads, head_dim)
    k = (kv_src @ params["wk"]).reshape(B, Sk, n_kv, head_dim)
    v = (kv_src @ params["wv"]).reshape(B, Sk, n_kv, head_dim)
    if rope_fn is not None:
        q = rope_fn(q, q_positions)
        k = rope_fn(k, k_positions if k_positions is not None
                    else (q_positions if kv_input is None
                          else k_mask_positions))
    if use_pallas:
        from ..kernels.ops import flash_attention
        out = flash_attention(q, k, v, q_positions=mask_positions,
                              k_positions=k_mask_positions, causal=causal,
                              window=window, attn_softcap=attn_softcap)
    else:
        out = chunked_attention(q, k, v, q_positions=mask_positions,
                                k_positions=k_mask_positions, causal=causal,
                                window=window, attn_softcap=attn_softcap,
                                chunk=chunk)
    return out.reshape(B, S, n_heads * head_dim) @ params["wo"]


# ---------------------------------------------------------------------------
# decode (single new token against a rotating KV cache)
# ---------------------------------------------------------------------------

def init_attn_cache(batch: int, buf_len: int, n_kv: int, head_dim: int, dtype):
    return {
        "k": jnp.zeros((batch, buf_len, n_kv, head_dim), dtype),
        "v": jnp.zeros((batch, buf_len, n_kv, head_dim), dtype),
        "slot_pos": jnp.full((buf_len,), -1, jnp.int32),
    }


def init_paged_attn_cache(n_pages: int, page_size: int, n_kv: int,
                          head_dim: int, dtype):
    """Paged KV pool for one attention layer (ISSUE 7, DESIGN §14).

    Unlike the rotating buffer above there is no per-sequence axis: pages
    are a shared pool, and each serve slot owns an ordered list of page ids
    (the page table, held OUTSIDE the cache by the scheduler).  Page 0 is
    reserved as a scratch page by convention — idle/stalled slots write
    there and length masks keep it from ever being read.
    """
    return {
        "k_pages": jnp.zeros((n_pages, page_size, n_kv, head_dim), dtype),
        "v_pages": jnp.zeros((n_pages, page_size, n_kv, head_dim), dtype),
    }


def attn_decode_paged(params, cache, x, positions, page_table, *,
                      n_heads: int, n_kv: int, head_dim: int,
                      rope_fn: Callable, attn_softcap: float = 0.0,
                      window: int = 0, backend: str = "auto"):
    """Paged-cache decode: one new token per slot at PER-SLOT positions.

    x: (S, 1, d); positions: (S,) int32 — the position each slot's token is
    written at (so slots at different depths decode in one batch, the
    capability the rotating ``attn_decode`` lacks: its scalar ``pos`` is
    shared by the whole batch).  page_table: (S, max_pages) int32 physical
    page ids in logical order; cache: init_paged_attn_cache pools.

    Mirrors ``attn_decode``'s arithmetic exactly (same einsum chain on the
    gathered logical buffer on the jnp oracle path) so the two are bitwise
    equal on CPU when every slot sits at the same position and the logical
    capacities match — the parity pin in tests/test_serve.py.
    """
    from ..kernels.ops import paged_decode_attention

    S = x.shape[0]
    page = cache["k_pages"].shape[1]
    q = (x @ params["wq"]).reshape(S, 1, n_heads, head_dim)
    k = (x @ params["wk"]).reshape(S, 1, n_kv, head_dim)
    v = (x @ params["wv"]).reshape(S, 1, n_kv, head_dim)
    if rope_fn is not None:
        q = rope_fn(q, positions[:, None])
        k = rope_fn(k, positions[:, None])

    # scatter the new token through the page table; idle slots resolve to
    # the scratch page (table entry 0) and are never read back
    ppage = jnp.take_along_axis(page_table, (positions // page)[:, None],
                                axis=1)[:, 0]
    off = positions % page
    kc = cache["k_pages"].at[ppage, off].set(
        k[:, 0].astype(cache["k_pages"].dtype))
    vc = cache["v_pages"].at[ppage, off].set(
        v[:, 0].astype(cache["v_pages"].dtype))

    o = paged_decode_attention(q.reshape(S, n_heads, head_dim), kc, vc,
                               page_table, positions + 1, window=window,
                               attn_softcap=attn_softcap, backend=backend)
    out = o.reshape(S, 1, n_heads * head_dim).astype(x.dtype) @ params["wo"]
    return out, {"k_pages": kc, "v_pages": vc}


def attn_decode(params, cache, x, pos, *, n_heads: int, n_kv: int,
                head_dim: int, rope_fn: Callable, attn_softcap: float = 0.0):
    """x: (B, 1, d); pos: scalar int32 (same for all sequences).

    Returns (out (B,1,d), new_cache).  Rotating buffer: slot = pos % buf_len.
    """
    B = x.shape[0]
    buf = cache["k"].shape[1]
    q = (x @ params["wq"]).reshape(B, 1, n_heads, head_dim)
    k = (x @ params["wk"]).reshape(B, 1, n_kv, head_dim)
    v = (x @ params["wv"]).reshape(B, 1, n_kv, head_dim)
    posv = jnp.reshape(pos, (1,))
    if rope_fn is not None:
        q = rope_fn(q, posv)
        k = rope_fn(k, posv)

    slot = pos % buf
    kc = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                      (0, slot, 0, 0))
    vc = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                      (0, slot, 0, 0))
    sp = jax.lax.dynamic_update_slice(cache["slot_pos"],
                                      jnp.reshape(pos, (1,)).astype(jnp.int32),
                                      (slot,))

    G = n_heads // n_kv
    qg = q.reshape(B, n_kv, G, head_dim)
    s = jnp.einsum("bkgd,bwkd->bkgw", qg.astype(jnp.float32),
                   kc.astype(jnp.float32)) * head_dim ** -0.5
    if attn_softcap:
        s = softcap(s, attn_softcap)
    valid = (sp >= 0) & (sp <= pos)
    s = jnp.where(valid[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgw,bwkd->bkgd", p, vc.astype(jnp.float32))
    out = o.reshape(B, 1, n_heads * head_dim).astype(x.dtype) @ params["wo"]
    return out, {"k": kc, "v": vc, "slot_pos": sp}
