"""Conditional sharding hints: apply lax.with_sharding_constraint only when
the current (abstract) mesh actually has the named axes — model code stays
runnable on a bare CPU (tests) and acquires the right activation shardings
under the production mesh (dry-run / real launch)."""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P


def current_mesh():
    """The mesh of the surrounding `with mesh:` / set_mesh context, or None.

    jax >= 0.6 exposes it as the abstract mesh; on jax 0.4.x fall back to
    the thread-local physical mesh the context manager installs.
    """
    try:
        am = jax.sharding.get_abstract_mesh()
        if tuple(getattr(am, "axis_names", ()) or ()):
            return am
    except AttributeError:
        pass
    try:
        pm = jax._src.mesh.thread_resources.env.physical_mesh
        if not pm.empty:
            return pm
    except Exception:  # pragma: no cover - internal layout changed
        pass
    return None


def mesh_axes() -> tuple:
    m = current_mesh()
    return tuple(getattr(m, "axis_names", ()) or ()) if m is not None else ()


def _filter(spec_entry, axes):
    if spec_entry is None:
        return None
    if isinstance(spec_entry, (tuple, list)):
        kept = tuple(a for a in spec_entry if a in axes)
        return kept if kept else None
    return spec_entry if spec_entry in axes else None


def hint(x, *spec):
    """with_sharding_constraint(x, P(*spec)) with unknown axes dropped.
    No-op when there is no surrounding mesh."""
    axes = mesh_axes()
    if not axes:
        return x
    filtered = [_filter(s, axes) for s in spec]
    if all(s is None for s in filtered):
        return x
    return jax.lax.with_sharding_constraint(x, P(*filtered))


import contextlib
import threading

_CTX = threading.local()


def batch_axes():
    """Mesh axes that shard the activation batch dim in the CURRENT context.
    Serving (pjit, batch is global): ('pod', 'data') — the default.
    DPSGD training (under vmap over learners with spmd_axis_name): () — the
    learner axis is handled by vmap itself and the per-learner batch is
    unsharded."""
    return getattr(_CTX, "batch_axes", DATA_AXES)


@contextlib.contextmanager
def activation_batch_axes(axes):
    prev = getattr(_CTX, "batch_axes", DATA_AXES)
    _CTX.batch_axes = tuple(axes)
    try:
        yield
    finally:
        _CTX.batch_axes = prev


def residual_hint(x):
    """Constrain a (B, S, d) residual-stream activation: batch over the
    context's batch axes, S and d replicated over `model` — forces XLA's
    SPMD propagation into the Megatron pattern (one (B,S,d) all-reduce per
    row-parallel matmul instead of two (B,S,ff) ones; see EXPERIMENTS H2)."""
    return hint(x, batch_axes(), *([None] * (x.ndim - 1)))


def has_axis(name: str) -> bool:
    return name in mesh_axes()


def axis_size(name: str) -> int:
    m = current_mesh()
    if m is None:
        return 1
    try:
        return dict(zip(m.axis_names, m.axis_sizes))[name]
    except (AttributeError, KeyError):
        try:
            return m.shape[name]
        except Exception:
            return 1


DATA_AXES = ("pod", "data")
