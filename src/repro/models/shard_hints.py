"""Conditional sharding hints: apply lax.with_sharding_constraint only when
the current (abstract) mesh actually has the named axes — model code stays
runnable on a bare CPU (tests) and acquires the right activation shardings
under the production mesh (dry-run / real launch)."""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P


def mesh_axes() -> tuple:
    try:
        am = jax.sharding.get_abstract_mesh()
    except Exception:  # pragma: no cover - old jax
        return ()
    return tuple(getattr(am, "axis_names", ()) or ())


def _filter(spec_entry, axes):
    if spec_entry is None:
        return None
    if isinstance(spec_entry, (tuple, list)):
        kept = tuple(a for a in spec_entry if a in axes)
        return kept if kept else None
    return spec_entry if spec_entry in axes else None


def hint(x, *spec):
    """with_sharding_constraint(x, P(*spec)) with unknown axes dropped.
    No-op when there is no surrounding mesh."""
    axes = mesh_axes()
    if not axes:
        return x
    filtered = [_filter(s, axes) for s in spec]
    if all(s is None for s in filtered):
        return x
    return jax.lax.with_sharding_constraint(x, P(*filtered))


import contextlib
import threading

_CTX = threading.local()


def batch_axes():
    """Mesh axes that shard the activation batch dim in the CURRENT context.
    Serving (pjit, batch is global): ('pod', 'data') — the default.
    DPSGD training (under vmap over learners with spmd_axis_name): () — the
    learner axis is handled by vmap itself and the per-learner batch is
    unsharded."""
    return getattr(_CTX, "batch_axes", DATA_AXES)


@contextlib.contextmanager
def activation_batch_axes(axes):
    prev = getattr(_CTX, "batch_axes", DATA_AXES)
    _CTX.batch_axes = tuple(axes)
    try:
        yield
    finally:
        _CTX.batch_axes = prev


def residual_hint(x):
    """Constrain a (B, S, d) residual-stream activation: batch over the
    context's batch axes, S and d replicated over `model` — forces XLA's
    SPMD propagation into the Megatron pattern (one (B,S,d) all-reduce per
    row-parallel matmul instead of two (B,S,ff) ones; see EXPERIMENTS H2)."""
    return hint(x, batch_axes(), *([None] * (x.ndim - 1)))


def has_axis(name: str) -> bool:
    return name in mesh_axes()


def axis_size(name: str) -> int:
    try:
        am = jax.sharding.get_abstract_mesh()
        return dict(zip(am.axis_names, am.axis_sizes))[name]
    except Exception:
        return 1


DATA_AXES = ("pod", "data")
