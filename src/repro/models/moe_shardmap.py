"""Expert-parallel MoE with explicit all-to-all dispatch (shard_map).

The pjit/einsum dispatch in moe.py lets XLA's SPMD partitioner handle the
scatter/gather — which it does by replicating the (T*k, d) combine tensors
and all-reducing them over `model` (measured 23 TB of per-step link traffic
for qwen3-moe prefill_32k; sharding hints make it WORSE — EXPERIMENTS §H1).

This backend states the communication explicitly, the way TPU MoE systems
actually run (GShard/Switch/MaxText):

  per device (one (data, model) coordinate):
    1. route its LOCAL tokens (seq is additionally split over `model`)
    2. pack tokens into per-destination-rank buffers (M, C_r, d)
    3. lax.all_to_all over `model`  →  each rank receives its experts' tokens
    4. local capacity-bucketed expert FFN (E_loc = E / M experts per rank)
    5. reverse all_to_all, unpack, gate-weighted combine

Per-device link traffic: 2 * (M-1)/M * C_r * M * d * bytes ≈ 2 * cf * k *
T_loc * d — independent of E and ~3 orders of magnitude below the pjit
fallback at prefill_32k scale.

Requires: E % model_size == 0 and S % model_size == 0 (prefill/train
shapes); other cases fall back to moe.moe_forward.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

try:                                      # jax >= 0.6
    _shard_map = jax.shard_map
except AttributeError:                    # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map
from jax.sharding import PartitionSpec as P

from .shard_hints import (axis_size, batch_axes, current_mesh, has_axis,
                          mesh_axes)

__all__ = ["moe_forward_shardmap", "shardmap_applicable"]


def shardmap_applicable(n_experts: int, seq: int) -> bool:
    if not has_axis("model"):
        return False
    m = axis_size("model")
    return n_experts % m == 0 and seq % m == 0 and m > 1


def _local_moe(xt, router, w1, w3, w2, *, n_experts_local: int, top_k: int,
               n_ranks: int, cap_send: int, cap_expert: int):
    """One device's dispatch/FFN/combine.  xt: (T_loc, d) local tokens."""
    T, d = xt.shape

    logits = xt.astype(jnp.float32) @ router                    # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eids = jax.lax.top_k(probs, top_k)                   # (T, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # ---- pack per destination rank -------------------------------------
    tgt = (eids // n_experts_local).reshape(-1)                 # (T*k,)
    loc_e = (eids % n_experts_local).reshape(-1)
    oh = jax.nn.one_hot(tgt, n_ranks, dtype=jnp.int32)
    pos = (jnp.cumsum(oh, axis=0) - 1)
    pos = jnp.take_along_axis(pos, tgt[:, None], axis=1)[:, 0]
    keep = pos < cap_send
    se = jnp.where(keep, tgt, 0)
    sc = jnp.where(keep, pos, cap_send)                         # trash col
    src = jnp.repeat(xt, top_k, axis=0)
    send_x = jnp.zeros((n_ranks, cap_send + 1, d), xt.dtype) \
        .at[se, sc].set(src.astype(xt.dtype), mode="drop")[:, :cap_send]
    send_e = jnp.full((n_ranks, cap_send + 1), -1, jnp.int32) \
        .at[se, sc].set(jnp.where(keep, loc_e, -1), mode="drop")[:, :cap_send]

    # ---- exchange -------------------------------------------------------
    recv_x = jax.lax.all_to_all(send_x, "model", 0, 0, tiled=False)
    recv_e = jax.lax.all_to_all(send_e, "model", 0, 0, tiled=False)

    # ---- local expert buckets -------------------------------------------
    fe = recv_e.reshape(-1)                                      # (M*C_r,)
    fx = recv_x.reshape(-1, d)
    valid = fe >= 0
    fe_safe = jnp.where(valid, fe, 0)
    oh2 = jax.nn.one_hot(fe_safe, n_experts_local, dtype=jnp.int32) \
        * valid[:, None].astype(jnp.int32)
    pos2 = jnp.cumsum(oh2, axis=0) - 1
    pos2 = jnp.take_along_axis(pos2, fe_safe[:, None], axis=1)[:, 0]
    keep2 = valid & (pos2 < cap_expert)
    be = jnp.where(keep2, fe_safe, 0)
    bc = jnp.where(keep2, pos2, cap_expert)
    buf = jnp.zeros((n_experts_local, cap_expert + 1, d), xt.dtype) \
        .at[be, bc].set(fx, mode="drop")[:, :cap_expert]

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, w1)) \
        * jnp.einsum("ecd,edf->ecf", buf, w3)
    out = jnp.einsum("ecf,efd->ecd", h, w2)                      # (E_l, C_e, d)

    # ---- return to senders ----------------------------------------------
    ret = out[be, jnp.minimum(bc, cap_expert - 1)]
    ret = jnp.where(keep2[:, None], ret, 0.0).reshape(
        n_ranks, cap_send, d)
    back = jax.lax.all_to_all(ret, "model", 0, 0, tiled=False)

    # ---- combine ----------------------------------------------------------
    gathered = back[se, jnp.minimum(sc, cap_send - 1)]
    gathered = jnp.where(keep[:, None], gathered, 0.0)
    w = (gates.reshape(-1) * keep.astype(gates.dtype))[:, None]
    y = jnp.sum((gathered * w.astype(gathered.dtype)).reshape(T, top_k, d),
                axis=1)
    return y.astype(xt.dtype)


def moe_forward_shardmap(params, x, *, n_experts: int, top_k: int,
                         capacity_factor: float = 1.25):
    """x: (B, S, d) — inside pjit under a mesh with a `model` axis."""
    B, S, d = x.shape
    m = axis_size("model")
    axes = mesh_axes()
    d_axes = tuple(a for a in batch_axes() if a in axes)
    n_l = 1
    for a in d_axes:
        n_l *= axis_size(a)
    b_shard = d_axes if (B % max(n_l, 1) == 0 and n_l > 1) else None
    e_loc = n_experts // m
    b_loc = B // n_l if b_shard else B
    t_loc = b_loc * (S // m)
    cap_send = max(1, math.ceil(capacity_factor * top_k * t_loc / m))
    cap_expert = max(1, math.ceil(2.0 * m * cap_send / e_loc))

    local = partial(_local_moe, n_experts_local=e_loc, top_k=top_k,
                    n_ranks=m, cap_send=cap_send, cap_expert=cap_expert)

    def wrapper(x_loc, router, w1, w3, w2):
        bl, sl, _ = x_loc.shape
        y = local(x_loc.reshape(bl * sl, d), router, w1, w3, w2)
        return y.reshape(bl, sl, d)

    x_spec = P(b_shard, "model", None)
    return _shard_map(
        wrapper,
        mesh=current_mesh(),
        in_specs=(x_spec, P(None, None), P("model", None, None),
                  P("model", None, None), P("model", None, None)),
        out_specs=x_spec,
    )(x, params["router"], params["w1"], params["w3"], params["w2"])
