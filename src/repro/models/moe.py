"""Mixture-of-Experts FFN: top-k router + capacity-bucketed expert matmuls.

TPU-native dispatch (no GShard one-hot dispatch tensor, which would be
(tokens x E x C) and explode at 32k sequence): tokens are scattered into an
(E, C, d) buffer by (expert_id, rank-within-expert) computed with a cumsum —
a single XLA scatter — then three einsums run all experts, then a gather
brings results back and combine-weights sum the top-k contributions.

Sharding: expert axis E goes over the `model` mesh axis when E % model == 0
(expert parallelism, the all-to-all shows up in the dry-run collective
analysis); otherwise the hidden dim f is sharded (tensor parallelism).
Token overflow beyond capacity C = cf * k * T / E is dropped (standard);
combine weights of kept assignments are renormalized over the kept set.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import dense_init

__all__ = ["init_moe_params", "moe_forward"]


def init_moe_params(key, d_model: int, d_ff: int, n_experts: int, dtype):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "router": dense_init(k1, d_model, n_experts, jnp.float32),
        "w1": jax.vmap(lambda k: dense_init(k, d_model, d_ff, dtype))(
            jax.random.split(k2, n_experts)),
        "w3": jax.vmap(lambda k: dense_init(k, d_model, d_ff, dtype))(
            jax.random.split(k3, n_experts)),
        "w2": jax.vmap(lambda k: dense_init(k, d_ff, d_model, dtype))(
            jax.random.split(k4, n_experts)),
    }


def moe_forward(params, x, *, n_experts: int, top_k: int,
                capacity_factor: float = 1.25, return_aux: bool = False):
    """x: (B, S, d) -> (B, S, d) [+ aux losses dict].

    NOTE (EXPERIMENTS.md §Perf H1): under pjit SPMD this global scatter/
    gather dispatch replicates the (T*k, d) combine tensors and all-reduces
    them over `model` — with-sharding-constraint hints do NOT fix it (they
    add an extra all-gather; measured).  The production serving path uses
    moe_shardmap.moe_forward_shardmap (explicit all-to-all) instead.
    """
    B, S, d = x.shape
    T = B * S
    xt = x.reshape(T, d)

    logits = (xt.astype(jnp.float32) @ params["router"])       # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, top_k)        # (T, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)      # renormalize

    C = max(1, int(capacity_factor * top_k * T / n_experts))

    # rank of each (token, k) assignment within its expert, in token order
    flat_expert = expert_ids.reshape(-1)                        # (T*k,)
    onehot = jax.nn.one_hot(flat_expert, n_experts, dtype=jnp.int32)  # (T*k, E)
    ranks = jnp.cumsum(onehot, axis=0) - 1                      # 0-based
    rank_in_expert = jnp.take_along_axis(
        ranks, flat_expert[:, None], axis=1)[:, 0]              # (T*k,)
    keep = rank_in_expert < C

    # scatter tokens into (E, C, d); dropped assignments land in a trash row
    slot_e = jnp.where(keep, flat_expert, 0)
    slot_c = jnp.where(keep, rank_in_expert, C)                 # C = trash col
    buf = jnp.zeros((n_experts, C + 1, d), x.dtype)
    src = jnp.repeat(xt, top_k, axis=0)                         # (T*k, d)
    buf = buf.at[slot_e, slot_c].set(src.astype(buf.dtype), mode="drop")
    buf = buf[:, :C]                                            # (E, C, d)

    # expert FFN (swiglu) on every bucket
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, params["w1"])) \
        * jnp.einsum("ecd,edf->ecf", buf, params["w3"])
    out_buf = jnp.einsum("ecf,efd->ecd", h, params["w2"])       # (E, C, d)

    # gather back and combine
    gathered = out_buf[slot_e, jnp.minimum(slot_c, C - 1)]      # (T*k, d)
    gathered = jnp.where(keep[:, None], gathered, 0.0)
    w = (gate_vals.reshape(-1) * keep.astype(gate_vals.dtype))[:, None]
    yt = jnp.sum((gathered * w.astype(gathered.dtype)).reshape(T, top_k, d),
                 axis=1)
    y = yt.reshape(B, S, d)

    if not return_aux:
        return y
    # Switch-style load-balance aux loss
    me = jnp.mean(probs, axis=0)                                # (E,)
    ce = jnp.mean(jax.nn.one_hot(expert_ids[:, 0], n_experts), axis=0)
    aux = {"load_balance": n_experts * jnp.sum(me * ce),
           "dropped_frac": 1.0 - jnp.mean(keep.astype(jnp.float32))}
    return y, aux
