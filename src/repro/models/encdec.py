"""Encoder-decoder transformer (seamless-m4t-large-v2 backbone).

Encoder: bidirectional self-attention over *stub* audio-frame embeddings
(the conformer/mel frontend is the assignment's sanctioned carve-out).
Decoder: causal self-attention + cross-attention + FFN, over text tokens.
Both sides scan over layers.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .attention import (attn_decode, attn_forward, init_attn_cache,
                        init_attn_params)
from .layers import dense_init, dtype_of, embed_init, rms_norm, softcap
from .transformer import make_rope_fn


def _init_ff(key, d, ff, dt):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"w1": dense_init(k1, d, ff, dt), "w3": dense_init(k2, d, ff, dt),
            "w2": dense_init(k3, ff, d, dt)}


def _ff(p, x):
    return (jax.nn.silu(x @ p["w1"]) * (x @ p["w3"])) @ p["w2"]


def init_params(key, cfg: ModelConfig):
    dt = dtype_of(cfg.param_dtype)
    d = cfg.d_model
    ks = jax.random.split(key, 6)

    def enc_layer(k):
        k1, k2 = jax.random.split(k)
        return {"norm1": jnp.zeros((d,), jnp.float32),
                "attn": init_attn_params(k1, d, cfg.n_heads, cfg.n_kv_heads,
                                         cfg.head_dim_, dt),
                "norm2": jnp.zeros((d,), jnp.float32),
                "mlp": _init_ff(k2, d, cfg.d_ff, dt)}

    def dec_layer(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {"norm1": jnp.zeros((d,), jnp.float32),
                "self_attn": init_attn_params(k1, d, cfg.n_heads,
                                              cfg.n_kv_heads, cfg.head_dim_, dt),
                "norm_x": jnp.zeros((d,), jnp.float32),
                "cross_attn": init_attn_params(k2, d, cfg.n_heads,
                                               cfg.n_kv_heads, cfg.head_dim_, dt),
                "norm2": jnp.zeros((d,), jnp.float32),
                "mlp": _init_ff(k3, d, cfg.d_ff, dt)}

    return {
        "embed": embed_init(ks[0], cfg.padded_vocab, d, dt),
        "enc_layers": jax.vmap(enc_layer)(jax.random.split(ks[1], cfg.enc_layers)),
        "enc_norm": jnp.zeros((d,), jnp.float32),
        "dec_layers": jax.vmap(dec_layer)(jax.random.split(ks[2], cfg.n_layers)),
        "final_norm": jnp.zeros((d,), jnp.float32),
        "lm_head": dense_init(ks[3], d, cfg.padded_vocab, dt),
    }


def encode(params, cfg: ModelConfig, frames):
    """frames: (B, S_enc, d) stub audio embeddings -> (B, S_enc, d)."""
    S = frames.shape[1]
    pos = jnp.arange(S)
    rope_fn = make_rope_fn(cfg)

    @jax.checkpoint
    def layer_body(x, lp):
        h = rms_norm(x, lp["norm1"], cfg.norm_eps)
        h = attn_forward(lp["attn"], h, n_heads=cfg.n_heads,
                         n_kv=cfg.n_kv_heads, head_dim=cfg.head_dim_,
                         rope_fn=rope_fn, q_positions=pos, causal=False,
                         chunk=cfg.attn_chunk, use_pallas=cfg.use_pallas)
        x = x + h
        h = rms_norm(x, lp["norm2"], cfg.norm_eps)
        return x + _ff(lp["mlp"], h)

    def layer(x, lp):
        return layer_body(x, lp), None

    x, _ = jax.lax.scan(layer, frames, params["enc_layers"])
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def decode_train(params, cfg: ModelConfig, tokens, memory):
    """tokens: (B, S_dec); memory: (B, S_enc, d) -> logits."""
    x = params["embed"][tokens] * math.sqrt(cfg.d_model)
    S = x.shape[1]
    pos = jnp.arange(S)
    rope_fn = make_rope_fn(cfg)

    @jax.checkpoint
    def layer_body(x, lp):
        h = rms_norm(x, lp["norm1"], cfg.norm_eps)
        h = attn_forward(lp["self_attn"], h, n_heads=cfg.n_heads,
                         n_kv=cfg.n_kv_heads, head_dim=cfg.head_dim_,
                         rope_fn=rope_fn, q_positions=pos, causal=True,
                         chunk=cfg.attn_chunk, use_pallas=cfg.use_pallas)
        x = x + h
        h = rms_norm(x, lp["norm_x"], cfg.norm_eps)
        h = attn_forward(lp["cross_attn"], h, n_heads=cfg.n_heads,
                         n_kv=cfg.n_kv_heads, head_dim=cfg.head_dim_,
                         rope_fn=rope_fn, q_positions=pos, kv_input=memory,
                         causal=False, chunk=cfg.attn_chunk,
                         use_pallas=cfg.use_pallas)
        x = x + h
        h = rms_norm(x, lp["norm2"], cfg.norm_eps)
        return x + _ff(lp["mlp"], h)

    def layer(x, lp):
        return layer_body(x, lp), None

    x, _ = jax.lax.scan(layer, x, params["dec_layers"])
    h = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return h @ params["lm_head"]


def apply(params, cfg: ModelConfig, frames, tokens):
    memory = encode(params, cfg, frames)
    return decode_train(params, cfg, tokens, memory)


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def init_cache(params, cfg: ModelConfig, frames, buf_len: int):
    """Runs the encoder once and pre-computes per-layer cross K/V."""
    memory = encode(params, cfg, frames)
    B = memory.shape[0]
    dt = dtype_of(cfg.param_dtype)

    def one_layer(lp):
        Sk = memory.shape[1]
        k = (memory @ lp["cross_attn"]["wk"]).reshape(
            B, Sk, cfg.n_kv_heads, cfg.head_dim_)
        v = (memory @ lp["cross_attn"]["wv"]).reshape(
            B, Sk, cfg.n_kv_heads, cfg.head_dim_)
        rope_fn = make_rope_fn(cfg)
        if rope_fn is not None:
            k = rope_fn(k, jnp.arange(Sk))
        return {"xk": k.astype(dt), "xv": v.astype(dt)}

    cross = jax.vmap(one_layer)(params["dec_layers"])
    self_c = init_attn_cache(B, buf_len, cfg.n_kv_heads, cfg.head_dim_, dt)
    self_c = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (cfg.n_layers,) + x.shape).copy(),
        self_c)
    return {"cross": cross, "self": self_c}


def decode_step(params, cfg: ModelConfig, cache, tokens, pos):
    """tokens: (B, 1) -> (logits (B, 1, V), new_cache)."""
    x = params["embed"][tokens] * math.sqrt(cfg.d_model)
    rope_fn = make_rope_fn(cfg)

    def layer(x, inp):
        lp, cc, xc = inp
        h = rms_norm(x, lp["norm1"], cfg.norm_eps)
        h, cc = attn_decode(lp["self_attn"], cc, h, pos, n_heads=cfg.n_heads,
                            n_kv=cfg.n_kv_heads, head_dim=cfg.head_dim_,
                            rope_fn=rope_fn)
        x = x + h
        # cross attention against precomputed memory K/V (no cache update)
        h = rms_norm(x, lp["norm_x"], cfg.norm_eps)
        B = x.shape[0]
        q = (h @ lp["cross_attn"]["wq"]).reshape(B, 1, cfg.n_heads,
                                                 cfg.head_dim_)
        if rope_fn is not None:
            q = rope_fn(q, jnp.reshape(pos, (1,)))
        G = cfg.n_heads // cfg.n_kv_heads
        qg = q.reshape(B, cfg.n_kv_heads, G, cfg.head_dim_)
        s = jnp.einsum("bkgd,bwkd->bkgw", qg.astype(jnp.float32),
                       xc["xk"].astype(jnp.float32)) * cfg.head_dim_ ** -0.5
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bkgw,bwkd->bkgd", p, xc["xv"].astype(jnp.float32))
        o = o.reshape(B, 1, cfg.n_heads * cfg.head_dim_).astype(x.dtype)
        x = x + o @ lp["cross_attn"]["wo"]
        h = rms_norm(x, lp["norm2"], cfg.norm_eps)
        return x + _ff(lp["mlp"], h), cc

    x, new_self = jax.lax.scan(
        layer, x, (params["dec_layers"], cache["self"], cache["cross"]))
    h = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return h @ params["lm_head"], {"cross": cache["cross"], "self": new_self}
