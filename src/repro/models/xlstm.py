"""xLSTM blocks (Beck et al., arXiv:2405.04517): mLSTM + sLSTM.

TPU adaptation:
  * mLSTM — the matrix-memory recurrence C_t = f_t C_{t-1} + i_t v_t k_t^T is
    evaluated in the *chunkwise-parallel* form (intra-chunk quadratic
    attention with a stabilized log-space decay matrix, inter-chunk sequential
    state passing).  This is MXU-friendly and needs no per-step state storage.
  * sLSTM — genuinely sequential (recurrent weights R act on h_{t-1});
    implemented as lax.scan over time with rematerialized chunks.  It is the
    one layer type that cannot be parallelized over sequence — noted in
    DESIGN.md; it is cheap (d_model=1024).

Both use exponential gating with the m-stabilizer from the paper.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .layers import dense_init, rms_norm

NEG = -1e30


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def init_mlstm_params(key, d_model: int, n_heads: int, dtype, expand: int = 2):
    di = expand * d_model
    ks = jax.random.split(key, 8)
    return {
        "norm": jnp.zeros((d_model,), jnp.float32),
        "up": dense_init(ks[0], d_model, 2 * di, dtype),     # x and gate z
        "conv_w": (jax.random.normal(ks[1], (4, di)) / 2.0).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "wq": dense_init(ks[2], di, di, dtype),
        "wk": dense_init(ks[3], di, di, dtype),
        "wv": dense_init(ks[4], di, di, dtype),
        "w_igate": dense_init(ks[5], di, n_heads, jnp.float32, scale=0.01),
        "w_fgate": dense_init(ks[6], di, n_heads, jnp.float32, scale=0.01),
        "fgate_b": jnp.full((n_heads,), 3.0, jnp.float32),   # open forget gates
        "head_norm": jnp.zeros((di,), jnp.float32),
        "down": dense_init(ks[7], di, d_model, dtype),
    }


def _causal_conv(x, w, b):
    """depthwise causal conv, kernel size w.shape[0]; x: (B, S, d)."""
    K = w.shape[0]
    B, S, d = x.shape
    pad = jnp.zeros((B, K - 1, d), x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + S] * w[i] for i in range(K))
    return jax.nn.silu(out + b)


def mlstm_chunkwise(q, k, v, igate, fgate, chunk: int, state=None,
                    return_state: bool = False):
    """q,k,v: (B,S,H,dh); igate,fgate: (B,S,H) raw logits.  Stabilized
    chunkwise-parallel evaluation of the mLSTM recurrence."""
    B, S, H, dh = q.shape
    c = min(chunk, S)
    assert S % c == 0
    nc = S // c
    scale = dh ** -0.5

    def split(x):
        return x.reshape(B, nc, c, *x.shape[2:]).transpose(1, 0, *range(2, x.ndim + 1))
    qs, ks_, vs = split(q * scale), split(k), split(v)
    ig, fg = split(igate), split(fgate)          # (nc, B, c, H)

    if state is None:
        C0 = jnp.zeros((B, H, dh, dh), jnp.float32)
        n0 = jnp.zeros((B, H, dh), jnp.float32)
        m0 = jnp.full((B, H), 0.0, jnp.float32)
    else:
        C0, n0, m0 = state["C"], state["n"], state["m"]

    @jax.checkpoint
    def one_chunk(carry, inp):
        C, n, m_run = carry
        qb, kb, vb, ib, fb = inp                  # (B,c,H,dh) / (B,c,H)
        logf = jax.nn.log_sigmoid(fb.astype(jnp.float32))        # (B,c,H)
        cum = jnp.cumsum(logf, axis=1)                           # inclusive
        # Dlog[t,s] = cum_t - cum_s + i_s   (valid for s <= t)
        dlog = (cum[:, :, None] - cum[:, None, :]
                + ib.astype(jnp.float32)[:, None, :])            # (B,c,c,H)
        tri = jnp.tril(jnp.ones((c, c), bool))
        dlog = jnp.where(tri[None, :, :, None], dlog, NEG)
        m_intra = jnp.max(dlog, axis=2)                          # (B,c,H)
        m_inter = m_run[:, None] + cum                           # (B,c,H)
        m_t = jnp.maximum(m_intra, m_inter)
        d_mat = jnp.exp(dlog - m_t[:, :, None])                  # (B,c,c,H)
        inter_scale = jnp.exp(m_inter - m_t)                     # (B,c,H)

        qf = qb.astype(jnp.float32)
        kf = kb.astype(jnp.float32)
        vf = vb.astype(jnp.float32)
        scores = jnp.einsum("bthd,bshd->btsh", qf, kf) * d_mat
        num = (jnp.einsum("btsh,bshd->bthd", scores, vf)
               + inter_scale[..., None] * jnp.einsum("bthd,bhde->bthe", qf, C))
        # n_t = inter_scale * n_prev + sum_s D_ts k_s ;  denom = |q . n_t|
        n_t = (jnp.einsum("btsh,bshd->bthd", d_mat, kf)
               + inter_scale[..., None] * n[:, None])
        denom = jnp.abs(jnp.einsum("bthd,bthd->bth", qf, n_t))
        h = num / jnp.maximum(denom, jnp.exp(-m_t))[..., None]   # (B,c,H,dh)

        # chunk-end state
        last_cum = cum[:, -1]                                    # (B,H)
        u = last_cum[:, None] - cum + ib.astype(jnp.float32)     # (B,c,H)
        m_new = jnp.maximum(m_run + last_cum, jnp.max(u, axis=1))
        sc_old = jnp.exp(m_run + last_cum - m_new)               # (B,H)
        sc_in = jnp.exp(u - m_new[:, None])                      # (B,c,H)
        C_new = (sc_old[..., None, None] * C
                 + jnp.einsum("bsh,bshd,bshe->bhde", sc_in, kf, vf))
        n_new = (sc_old[..., None] * n
                 + jnp.einsum("bsh,bshd->bhd", sc_in, kf))
        return (C_new, n_new, m_new), h

    (C, n, m), hs = jax.lax.scan(one_chunk, (C0, n0, m0), (qs, ks_, vs, ig, fg))
    h = hs.transpose(1, 0, 2, 3, 4).reshape(B, S, H, dh).astype(q.dtype)
    if return_state:
        return h, {"C": C, "n": n, "m": m}
    return h


def mlstm_block_forward(params, x, *, n_heads: int, expand: int = 2,
                        chunk: int = 64, norm_eps: float = 1e-6):
    """Full mLSTM residual block.  x: (B, S, d)."""
    B, S, d = x.shape
    di = expand * d
    dh = di // n_heads
    h = rms_norm(x, params["norm"], norm_eps)
    up = h @ params["up"]
    xi, z = jnp.split(up, 2, axis=-1)
    xc = _causal_conv(xi, params["conv_w"], params["conv_b"])
    q = (xc @ params["wq"]).reshape(B, S, n_heads, dh)
    k = (xc @ params["wk"]).reshape(B, S, n_heads, dh)
    v = (xi @ params["wv"]).reshape(B, S, n_heads, dh)
    ig = xc.astype(jnp.float32) @ params["w_igate"]
    fg = xc.astype(jnp.float32) @ params["w_fgate"] + params["fgate_b"]
    o = mlstm_chunkwise(q, k, v, ig, fg, chunk).reshape(B, S, di)
    o = rms_norm(o, params["head_norm"], norm_eps)
    o = o * jax.nn.silu(z)
    return x + o @ params["down"]


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def init_slstm_params(key, d_model: int, n_heads: int, dtype,
                      ff_factor: float = 4.0 / 3.0):
    dh = d_model // n_heads
    dff = int(2 * ff_factor * d_model)
    ks = jax.random.split(key, 5)
    return {
        "norm": jnp.zeros((d_model,), jnp.float32),
        "w": dense_init(ks[0], d_model, 4 * d_model, dtype),   # z i f o
        "r": (jax.random.normal(ks[1], (n_heads, dh, 4 * dh))
              / math.sqrt(dh)).astype(dtype),
        "b": jnp.concatenate([jnp.zeros((2 * d_model,)),
                              jnp.full((d_model,), 3.0),
                              jnp.zeros((d_model,))]).astype(jnp.float32),
        "head_norm": jnp.zeros((d_model,), jnp.float32),
        "up": dense_init(ks[2], d_model, 2 * dff, dtype),
        "down": dense_init(ks[3], dff, d_model, dtype),
    }


def slstm_scan(wx, r, h0, c0, n0, m0, n_heads: int, chunk: int = 64):
    """wx: (B, S, 4d) precomputed input contributions.  Sequential scan."""
    B, S, d4 = wx.shape
    d = d4 // 4
    dh = d // n_heads
    c_ = min(chunk, S)
    nc = S // c_
    wxc = wx.reshape(B, nc, c_, d4).transpose(1, 0, 2, 3)

    @jax.checkpoint
    def one_chunk(carry, xs):
        def step(carry, wxt):
            h, c, n, m = carry                     # h: (B, H, dh) etc.
            rec = jnp.einsum("bhd,hde->bhe", h, r.astype(jnp.float32))
            pre = wxt.reshape(B, n_heads, 4 * dh).astype(jnp.float32) + rec
            z, i, f, o = jnp.split(pre, 4, axis=-1)
            z = jnp.tanh(z)
            o = jax.nn.sigmoid(o)
            m_new = jnp.maximum(f + m, i)
            fp = jnp.exp(f + m - m_new)
            ip = jnp.exp(i - m_new)
            c_new = fp * c + ip * z
            n_new = fp * n + ip
            h_new = o * c_new / jnp.maximum(n_new, 1e-6)
            return (h_new, c_new, n_new, m_new), h_new
        return jax.lax.scan(step, carry, xs.transpose(1, 0, 2))

    carry = (h0, c0, n0, m0)
    carry, hs = jax.lax.scan(one_chunk, carry, wxc)
    # hs: (nc, c, B, H, dh) -> (B, S, d)
    hs = hs.transpose(2, 0, 1, 3, 4).reshape(B, S, d)
    return hs, carry


def slstm_block_forward(params, x, *, n_heads: int, chunk: int = 64,
                        norm_eps: float = 1e-6):
    B, S, d = x.shape
    dh = d // n_heads
    h = rms_norm(x, params["norm"], norm_eps)
    wx = h @ params["w"] + params["b"]
    # regroup (z|i|f|o per model-dim) into per-head interleave
    wx = wx.reshape(B, S, 4, n_heads, dh).transpose(0, 1, 3, 2, 4) \
           .reshape(B, S, 4 * d)
    z0 = jnp.zeros((B, n_heads, dh), jnp.float32)
    m0 = jnp.full((B, n_heads, dh), 0.0, jnp.float32)
    hs, _ = slstm_scan(wx, params["r"], z0, z0, z0, m0, n_heads, chunk)
    hs = rms_norm(hs.astype(x.dtype), params["head_norm"], norm_eps)
    out = x + hs
    # gated FF (factor 4/3 GLU) — part of the sLSTM block per the paper
    ff = rms_norm(out, params["norm"] * 0, norm_eps) @ params["up"]
    a, b = jnp.split(ff, 2, axis=-1)
    return out + (jax.nn.silu(a) * b) @ params["down"]


# ---------------------------------------------------------------------------
# decode (single step)
# ---------------------------------------------------------------------------

def init_mlstm_cache(batch: int, d_model: int, n_heads: int, expand: int = 2,
                     dtype=jnp.float32):
    di = expand * d_model
    dh = di // n_heads
    return {"C": jnp.zeros((batch, n_heads, dh, dh), jnp.float32),
            "n": jnp.zeros((batch, n_heads, dh), jnp.float32),
            "m": jnp.zeros((batch, n_heads), jnp.float32),
            "conv": jnp.zeros((batch, 3, di), dtype)}


def mlstm_block_decode(params, cache, x, *, n_heads: int, expand: int = 2,
                       norm_eps: float = 1e-6):
    B, _, d = x.shape
    di = expand * d
    dh = di // n_heads
    h = rms_norm(x, params["norm"], norm_eps)
    up = h @ params["up"]
    xi, z = jnp.split(up, 2, axis=-1)                  # (B,1,di)
    hist = jnp.concatenate([cache["conv"], xi[:, 0:1].astype(cache["conv"].dtype)],
                           axis=1)                      # (B,4,di)
    xc = jnp.einsum("bcd,cd->bd", hist, params["conv_w"])[:, None]
    xc = jax.nn.silu(xc + params["conv_b"])
    q = (xc @ params["wq"]).reshape(B, n_heads, dh) * dh ** -0.5
    k = (xc @ params["wk"]).reshape(B, n_heads, dh)
    v = (xi @ params["wv"]).reshape(B, n_heads, dh)
    ig = (xc.astype(jnp.float32) @ params["w_igate"])[:, 0]
    fg = (xc.astype(jnp.float32) @ params["w_fgate"])[:, 0] + params["fgate_b"]
    logf = jax.nn.log_sigmoid(fg)
    m_new = jnp.maximum(logf + cache["m"], ig)
    fp = jnp.exp(logf + cache["m"] - m_new)
    ip = jnp.exp(ig - m_new)
    kf = k.astype(jnp.float32)
    C = fp[..., None, None] * cache["C"] + ip[..., None, None] \
        * jnp.einsum("bhd,bhe->bhde", kf, v.astype(jnp.float32))
    n = fp[..., None] * cache["n"] + ip[..., None] * kf
    qf = q.astype(jnp.float32)
    num = jnp.einsum("bhd,bhde->bhe", qf, C)
    den = jnp.abs(jnp.einsum("bhd,bhd->bh", qf, n))
    hval = num / jnp.maximum(den, jnp.exp(-m_new))[..., None]
    o = hval.reshape(B, 1, di).astype(x.dtype)
    o = rms_norm(o, params["head_norm"], norm_eps)
    o = o * jax.nn.silu(z)
    out = x + o @ params["down"]
    return out, {"C": C, "n": n, "m": m_new, "conv": hist[:, 1:]}


def init_slstm_cache(batch: int, d_model: int, n_heads: int):
    dh = d_model // n_heads
    z = jnp.zeros((batch, n_heads, dh), jnp.float32)
    return {"h": z, "c": z, "n": z, "m": z}


def slstm_block_decode(params, cache, x, *, n_heads: int,
                       norm_eps: float = 1e-6):
    B, _, d = x.shape
    dh = d // n_heads
    h = rms_norm(x, params["norm"], norm_eps)
    wx = (h @ params["w"] + params["b"])
    wx = wx.reshape(B, 1, 4, n_heads, dh).transpose(0, 1, 3, 2, 4) \
           .reshape(B, n_heads, 4 * dh)[:, :, :]
    rec = jnp.einsum("bhd,hde->bhe", cache["h"], params["r"].astype(jnp.float32))
    pre = wx.astype(jnp.float32) + rec
    z, i, f, o = jnp.split(pre, 4, axis=-1)
    z = jnp.tanh(z)
    o = jax.nn.sigmoid(o)
    m_new = jnp.maximum(f + cache["m"], i)
    fp = jnp.exp(f + cache["m"] - m_new)
    ip = jnp.exp(i - m_new)
    c_new = fp * cache["c"] + ip * z
    n_new = fp * cache["n"] + ip
    h_new = o * c_new / jnp.maximum(n_new, 1e-6)
    hs = rms_norm(h_new.reshape(B, 1, d).astype(x.dtype),
                  params["head_norm"], norm_eps)
    out = x + hs
    ff = rms_norm(out, params["norm"] * 0, norm_eps) @ params["up"]
    a, b = jnp.split(ff, 2, axis=-1)
    out = out + (jax.nn.silu(a) * b) @ params["down"]
    return out, {"h": h_new, "c": c_new, "n": n_new, "m": m_new}
