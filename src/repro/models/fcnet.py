"""The paper's MNIST network (Sec. 2): fully connected, two hidden layers of
50 units — used for the Fig. 2 / Fig. 4 / Fig. 5 reproductions."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import cross_entropy, dense_init


def init_params(key, in_dim: int = 784, hidden: int = 50, n_classes: int = 10):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w1": dense_init(k1, in_dim, hidden, jnp.float32),
        "b1": jnp.zeros((hidden,)),
        "w2": dense_init(k2, hidden, hidden, jnp.float32),
        "b2": jnp.zeros((hidden,)),
        "w3": dense_init(k3, hidden, n_classes, jnp.float32),
        "b3": jnp.zeros((n_classes,)),
    }


def apply(params, images):
    x = images.reshape(images.shape[0], -1)
    x = jax.nn.relu(x @ params["w1"] + params["b1"])
    x = jax.nn.relu(x @ params["w2"] + params["b2"])
    return x @ params["w3"] + params["b3"]


def loss_fn(params, batch):
    logits = apply(params, batch["image"])
    return cross_entropy(logits, batch["label"])


def accuracy(params, batch):
    logits = apply(params, batch["image"])
    return jnp.mean((jnp.argmax(logits, -1) == batch["label"]).astype(jnp.float32))
