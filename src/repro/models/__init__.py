from . import fcnet
from .model import ModelAPI, build_model, make_synthetic_batch

__all__ = ["ModelAPI", "build_model", "make_synthetic_batch", "fcnet"]
