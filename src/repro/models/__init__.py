from .model import ModelAPI, build_model, make_synthetic_batch
from . import fcnet

__all__ = ["ModelAPI", "build_model", "make_synthetic_batch", "fcnet"]
