"""Decoder-only LM assembled per ModelConfig.

Depth is expressed as n_periods x period, where a *period* is the repeating
heterogeneous block pattern (gemma2: [local, global]; jamba: 7 mamba + 1 attn
with MoE every 2nd layer; xlstm: [mLSTM, sLSTM]; dense: [attn]).  The stack is
a lax.scan over stacked period params, so the HLO is O(period), not O(depth)
— essential for compiling 88-layer models on one CPU core in the dry-run.

Layer spec = (mixer, mlp) with mixer in {attn, attn_local, mamba, mlstm,
slstm} and mlp in {dense, moe, none}.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .attention import (attn_decode, attn_decode_paged, attn_forward,
                        init_attn_cache, init_attn_params,
                        init_paged_attn_cache)
from .layers import (apply_mrope, apply_rope, dense_init, dtype_of,
                     embed_init, rms_norm, softcap)
from .mamba import (init_mamba_cache, init_mamba_params, mamba_decode,
                    mamba_forward)
from .moe import init_moe_params, moe_forward
from .xlstm import (init_mlstm_cache, init_mlstm_params, init_slstm_cache,
                    init_slstm_params, mlstm_block_decode, mlstm_block_forward,
                    slstm_block_decode, slstm_block_forward)


# ---------------------------------------------------------------------------
# period spec
# ---------------------------------------------------------------------------

def period_spec(cfg: ModelConfig) -> Tuple[Tuple[str, str], ...]:
    if cfg.block_period:
        spec = []
        for i, mixer in enumerate(cfg.block_period):
            if cfg.attn_layer_offset >= 0 and i == cfg.attn_layer_offset:
                mixer = "attn"
            if mixer in ("mlstm", "slstm"):
                mlp = "none"
            elif cfg.n_experts and cfg.moe_every and (i % cfg.moe_every
                                                      == cfg.moe_every - 1):
                mlp = "moe"
            else:
                mlp = "dense"
            spec.append((mixer, mlp))
        return tuple(spec)
    mlp = "moe" if cfg.n_experts else "dense"
    if cfg.attn_pattern == "local_global":
        return (("attn_local", mlp), ("attn", mlp))
    if cfg.attn_pattern == "sliding":
        return (("attn_local", mlp),)
    return (("attn", mlp),)


def n_periods(cfg: ModelConfig) -> int:
    p = len(period_spec(cfg))
    assert cfg.n_layers % p == 0, (cfg.n_layers, p)
    return cfg.n_layers // p


# ---------------------------------------------------------------------------
# rope closure
# ---------------------------------------------------------------------------

def make_rope_fn(cfg: ModelConfig):
    if not cfg.use_rope:
        return None
    if cfg.mrope_sections:
        return lambda x, pos: apply_mrope(x, pos, cfg.rope_theta,
                                          cfg.mrope_sections)
    return lambda x, pos: apply_rope(x, pos, cfg.rope_theta)


# ---------------------------------------------------------------------------
# per-layer init
# ---------------------------------------------------------------------------

def _init_layer(key, cfg: ModelConfig, mixer: str, mlp: str):
    dt = dtype_of(cfg.param_dtype)
    d = cfg.d_model
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"norm1": jnp.zeros((d,), jnp.float32)}
    if mixer in ("attn", "attn_local"):
        p["mixer"] = init_attn_params(k1, d, cfg.n_heads, cfg.n_kv_heads,
                                      cfg.head_dim_, dt)
    elif mixer == "mamba":
        p["mixer"] = init_mamba_params(k1, d, expand=cfg.ssm_expand,
                                       state=cfg.ssm_state, conv=cfg.ssm_conv,
                                       dtype=dt)
    elif mixer == "mlstm":
        p["mixer"] = init_mlstm_params(k1, d, cfg.n_heads, dt)
    elif mixer == "slstm":
        p["mixer"] = init_slstm_params(k1, d, cfg.n_heads, dt)
    else:
        raise ValueError(mixer)
    if mlp == "dense":
        p["norm2"] = jnp.zeros((d,), jnp.float32)
        p["mlp"] = {"w1": dense_init(k2, d, cfg.d_ff, dt),
                    "w3": dense_init(k3, d, cfg.d_ff, dt),
                    "w2": dense_init(jax.random.fold_in(k3, 1), cfg.d_ff, d, dt)}
    elif mlp == "moe":
        p["norm2"] = jnp.zeros((d,), jnp.float32)
        p["mlp"] = init_moe_params(k2, d, cfg.d_ff, cfg.n_experts, dt)
    return p


def init_params(key, cfg: ModelConfig):
    dt = dtype_of(cfg.param_dtype)
    spec = period_spec(cfg)
    np_ = n_periods(cfg)
    k_embed, k_blocks, k_head = jax.random.split(key, 3)

    def init_period(k):
        ks = jax.random.split(k, len(spec))
        return {f"l{i}": _init_layer(ks[i], cfg, mixer, mlp)
                for i, (mixer, mlp) in enumerate(spec)}

    params = {
        "embed": embed_init(k_embed, cfg.padded_vocab, cfg.d_model, dt),
        "periods": jax.vmap(init_period)(jax.random.split(k_blocks, np_)),
        "final_norm": jnp.zeros((cfg.d_model,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(k_head, cfg.d_model, cfg.padded_vocab, dt)
    return params


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _layer_forward(lp, x, cfg: ModelConfig, mixer: str, mlp: str, rope_fn,
                   positions):
    from .shard_hints import residual_hint
    x = residual_hint(x)
    if mixer in ("attn", "attn_local"):
        h = rms_norm(x, lp["norm1"], cfg.norm_eps)
        win = cfg.window if (mixer == "attn_local"
                             or cfg.attn_pattern == "sliding") else 0
        qpos = positions if not cfg.mrope_sections else positions
        # scalar positions for masking: use the time component for M-RoPE
        mask_pos = positions[0] if cfg.mrope_sections else positions
        h = attn_forward(lp["mixer"], h, n_heads=cfg.n_heads,
                         n_kv=cfg.n_kv_heads, head_dim=cfg.head_dim_,
                         rope_fn=rope_fn, q_positions=qpos,
                         window=win, attn_softcap=cfg.attn_softcap,
                         chunk=cfg.attn_chunk, use_pallas=cfg.use_pallas,
                         mask_positions=mask_pos)
        x = x + h
    elif mixer == "mamba":
        h = rms_norm(x, lp["norm1"], cfg.norm_eps)
        x = x + mamba_forward(lp["mixer"], h, expand=cfg.ssm_expand,
                              state=cfg.ssm_state, conv=cfg.ssm_conv,
                              scan_chunk=cfg.scan_chunk)
    elif mixer == "mlstm":
        x = mlstm_block_forward(lp["mixer"], x, n_heads=cfg.n_heads,
                                chunk=cfg.scan_chunk, norm_eps=cfg.norm_eps)
    elif mixer == "slstm":
        x = slstm_block_forward(lp["mixer"], x, n_heads=cfg.n_heads,
                                chunk=cfg.scan_chunk, norm_eps=cfg.norm_eps)
    if mlp == "dense":
        h = rms_norm(residual_hint(x), lp["norm2"], cfg.norm_eps)
        h = (jax.nn.silu(h @ lp["mlp"]["w1"]) * (h @ lp["mlp"]["w3"])) \
            @ lp["mlp"]["w2"]
        x = x + h
    elif mlp == "moe":
        h = rms_norm(residual_hint(x), lp["norm2"], cfg.norm_eps)
        if cfg.moe_backend == "shard_map":
            from .moe_shardmap import moe_forward_shardmap, shardmap_applicable
            if shardmap_applicable(cfg.n_experts, h.shape[1]):
                x = x + moe_forward_shardmap(
                    lp["mlp"], h, n_experts=cfg.n_experts,
                    top_k=cfg.experts_per_tok,
                    capacity_factor=cfg.capacity_factor)
                return x
        x = x + moe_forward(lp["mlp"], h, n_experts=cfg.n_experts,
                            top_k=cfg.experts_per_tok,
                            capacity_factor=cfg.capacity_factor)
    return x


def forward(params, cfg: ModelConfig, x, positions):
    """x: (B, S, d) input embeddings; positions: (S,) or (3, S) for M-RoPE.
    Returns final hidden states (B, S, d)."""
    spec = period_spec(cfg)
    rope_fn = make_rope_fn(cfg)

    @jax.checkpoint
    def period_body(x, pp):
        # remat per period: the layer scan would otherwise stack every
        # intermediate activation of every period for the backward pass
        # (measured 96 GB -> ~x/period for xlstm-350m train_4k)
        for i, (mixer, mlp) in enumerate(spec):
            x = _layer_forward(pp[f"l{i}"], x, cfg, mixer, mlp, rope_fn,
                               positions)
        return x

    def period_fn(x, pp):
        return period_body(x, pp), None

    x, _ = jax.lax.scan(period_fn, x, params["periods"])
    return x


def logits_from_hidden(params, cfg: ModelConfig, x):
    h = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = h @ head
    return softcap(logits, cfg.final_softcap)


def embed_tokens(params, cfg: ModelConfig, tokens):
    return params["embed"][tokens] * math.sqrt(cfg.d_model)


def apply(params, cfg: ModelConfig, tokens, positions=None, extra_embeds=None):
    """tokens: (B, S) -> logits (B, S_total, V).

    extra_embeds: (B, P, d) frontend stub embeddings (audio frames / vision
    patches) prepended to the token embeddings (vlm / audio families).
    """
    x = embed_tokens(params, cfg, tokens)
    if extra_embeds is not None:
        x = jnp.concatenate([extra_embeds.astype(x.dtype), x], axis=1)
    S = x.shape[1]
    if positions is None:
        if cfg.mrope_sections:
            positions = jnp.broadcast_to(jnp.arange(S), (3, S))
        else:
            positions = jnp.arange(S)
    h = forward(params, cfg, x, positions)
    return logits_from_hidden(params, cfg, h)


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def _layer_cache(cfg: ModelConfig, mixer: str, batch: int, buf_len: int):
    dt = dtype_of(cfg.param_dtype)
    if mixer in ("attn", "attn_local"):
        blen = min(buf_len, cfg.window) if (
            mixer == "attn_local" or cfg.attn_pattern == "sliding") else buf_len
        return init_attn_cache(batch, blen, cfg.n_kv_heads, cfg.head_dim_, dt)
    if mixer == "mamba":
        return init_mamba_cache(batch, cfg.d_model, expand=cfg.ssm_expand,
                                state=cfg.ssm_state, conv=cfg.ssm_conv, dtype=dt)
    if mixer == "mlstm":
        return init_mlstm_cache(batch, cfg.d_model, cfg.n_heads, dtype=dt)
    if mixer == "slstm":
        return init_slstm_cache(batch, cfg.d_model, cfg.n_heads)
    raise ValueError(mixer)


def init_cache(cfg: ModelConfig, batch: int, buf_len: int):
    spec = period_spec(cfg)
    np_ = n_periods(cfg)
    one = {f"l{i}": _layer_cache(cfg, mixer, batch, buf_len)
           for i, (mixer, _) in enumerate(spec)}
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (np_,) + x.shape).copy(), one)


def _layer_decode(lp, cc, x, pos, cfg: ModelConfig, mixer: str, mlp: str,
                  rope_fn):
    if mixer in ("attn", "attn_local"):
        h = rms_norm(x, lp["norm1"], cfg.norm_eps)
        rf = rope_fn
        if cfg.mrope_sections and rope_fn is not None:
            rf = lambda xx, p: rope_fn(xx, jnp.broadcast_to(p, (3,) + p.shape))
        h, cc = attn_decode(lp["mixer"], cc, h, pos, n_heads=cfg.n_heads,
                            n_kv=cfg.n_kv_heads, head_dim=cfg.head_dim_,
                            rope_fn=rf, attn_softcap=cfg.attn_softcap)
        x = x + h
    elif mixer == "mamba":
        h = rms_norm(x, lp["norm1"], cfg.norm_eps)
        h, cc = mamba_decode(lp["mixer"], cc, h, expand=cfg.ssm_expand,
                             state=cfg.ssm_state, conv=cfg.ssm_conv)
        x = x + h
    elif mixer == "mlstm":
        x, cc = mlstm_block_decode(lp["mixer"], cc, x, n_heads=cfg.n_heads,
                                   norm_eps=cfg.norm_eps)
    elif mixer == "slstm":
        x, cc = slstm_block_decode(lp["mixer"], cc, x, n_heads=cfg.n_heads,
                                   norm_eps=cfg.norm_eps)
    if mlp == "dense":
        h = rms_norm(x, lp["norm2"], cfg.norm_eps)
        h = (jax.nn.silu(h @ lp["mlp"]["w1"]) * (h @ lp["mlp"]["w3"])) \
            @ lp["mlp"]["w2"]
        x = x + h
    elif mlp == "moe":
        h = rms_norm(x, lp["norm2"], cfg.norm_eps)
        x = x + moe_forward(lp["mlp"], h, n_experts=cfg.n_experts,
                            top_k=cfg.experts_per_tok,
                            capacity_factor=cfg.capacity_factor)
    return x, cc


def decode_step(params, cfg: ModelConfig, cache, tokens, pos):
    """tokens: (B, 1); pos: scalar int32.  -> (logits (B, 1, V), new_cache)."""
    spec = period_spec(cfg)
    rope_fn = make_rope_fn(cfg)
    x = embed_tokens(params, cfg, tokens)

    def period_fn(x, inp):
        pp, cc = inp
        new_cc = {}
        for i, (mixer, mlp) in enumerate(spec):
            x, new_cc[f"l{i}"] = _layer_decode(pp[f"l{i}"], cc[f"l{i}"], x,
                                               pos, cfg, mixer, mlp, rope_fn)
        return x, new_cc

    x, new_cache = jax.lax.scan(period_fn, x, (params["periods"], cache))
    return logits_from_hidden(params, cfg, x), new_cache


# ---------------------------------------------------------------------------
# paged decode (per-slot positions — the serving path, ISSUE 7 / DESIGN §14)
# ---------------------------------------------------------------------------

def init_paged_cache(cfg: ModelConfig, n_slots: int, n_pages: int,
                     page_size: int):
    """Paged decode cache: attention layers share a page pool (no slot
    axis — ownership lives in the scheduler's page table); recurrent mixers
    (mamba/mlstm/slstm) keep their per-slot state caches, which are
    position-free and recycle via ``reset_slot``."""
    spec = period_spec(cfg)
    np_ = n_periods(cfg)
    dt = dtype_of(cfg.param_dtype)

    def layer(mixer):
        if mixer in ("attn", "attn_local"):
            return init_paged_attn_cache(n_pages, page_size, cfg.n_kv_heads,
                                         cfg.head_dim_, dt)
        return _layer_cache(cfg, mixer, n_slots, 1)

    one = {f"l{i}": layer(mixer) for i, (mixer, _) in enumerate(spec)}
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (np_,) + x.shape).copy(), one)


def _keep_slots(advance, new_cc, old_cc):
    """Per-slot select on a recurrent layer cache (leading axis = slot):
    slots with advance=False keep their old state bitwise.  Attention
    caches never come through here — their stale writes land in the
    scratch page and are excluded by length masks instead."""
    if advance is None:
        return new_cc

    def sel(n, o):
        return jnp.where(advance.reshape((-1,) + (1,) * (n.ndim - 1)), n, o)

    return jax.tree_util.tree_map(sel, new_cc, old_cc)


def _layer_decode_paged(lp, cc, x, positions, page_table, cfg: ModelConfig,
                        mixer: str, mlp: str, rope_fn, advance):
    if mixer in ("attn", "attn_local"):
        h = rms_norm(x, lp["norm1"], cfg.norm_eps)
        win = cfg.window if (mixer == "attn_local"
                             or cfg.attn_pattern == "sliding") else 0
        h, cc = attn_decode_paged(lp["mixer"], cc, h, positions, page_table,
                                  n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                                  head_dim=cfg.head_dim_, rope_fn=rope_fn,
                                  attn_softcap=cfg.attn_softcap, window=win)
        x = x + h
    elif mixer == "mamba":
        h = rms_norm(x, lp["norm1"], cfg.norm_eps)
        h, cc_new = mamba_decode(lp["mixer"], cc, h, expand=cfg.ssm_expand,
                                 state=cfg.ssm_state, conv=cfg.ssm_conv)
        cc = _keep_slots(advance, cc_new, cc)
        x = x + h
    elif mixer == "mlstm":
        x, cc_new = mlstm_block_decode(lp["mixer"], cc, x, n_heads=cfg.n_heads,
                                       norm_eps=cfg.norm_eps)
        cc = _keep_slots(advance, cc_new, cc)
    elif mixer == "slstm":
        x, cc_new = slstm_block_decode(lp["mixer"], cc, x, n_heads=cfg.n_heads,
                                       norm_eps=cfg.norm_eps)
        cc = _keep_slots(advance, cc_new, cc)
    if mlp == "dense":
        h = rms_norm(x, lp["norm2"], cfg.norm_eps)
        h = (jax.nn.silu(h @ lp["mlp"]["w1"]) * (h @ lp["mlp"]["w3"])) \
            @ lp["mlp"]["w2"]
        x = x + h
    elif mlp == "moe":
        h = rms_norm(x, lp["norm2"], cfg.norm_eps)
        x = x + moe_forward(lp["mlp"], h, n_experts=cfg.n_experts,
                            top_k=cfg.experts_per_tok,
                            capacity_factor=cfg.capacity_factor)
    return x, cc


def paged_decode_step(params, cfg: ModelConfig, cache, tokens, positions,
                      page_table, advance=None):
    """tokens: (S, 1); positions: (S,) int32 per-slot write positions;
    page_table: (S, max_pages) int32; advance: optional (S,) bool — slots
    with advance=False run through the batch shape-stably but keep their
    recurrent (mamba/mlstm/slstm) state bitwise unchanged (their attention
    write still lands in the scratch page).  The engine uses it for FREE
    and page-stalled slots; None means every slot advances.
    -> (logits (S, 1, V), new_cache).

    The paged cache never wraps: the scheduler enforces
    prompt + max_new_tokens <= max_pages * page_size per slot.
    """
    spec = period_spec(cfg)
    rope_fn = make_rope_fn(cfg)
    x = embed_tokens(params, cfg, tokens)

    def period_fn(x, inp):
        pp, cc = inp
        new_cc = {}
        for i, (mixer, mlp) in enumerate(spec):
            x, new_cc[f"l{i}"] = _layer_decode_paged(
                pp[f"l{i}"], cc[f"l{i}"], x, positions, page_table, cfg,
                mixer, mlp, rope_fn, advance)
        return x, new_cc

    x, new_cache = jax.lax.scan(period_fn, x, (params["periods"], cache))
    return logits_from_hidden(params, cfg, x), new_cache


def reset_slot(cache, slot):
    """Zero slot ``slot``'s recurrent (non-paged) per-slot states so a
    recycled slot starts from the init state.  Paged pools pass through
    untouched: freed pages are reclaimed by the scheduler's allocator and
    stale contents are never read (length masks)."""
    def leaf(path, x):
        if any(getattr(p, "key", None) in ("k_pages", "v_pages")
               for p in path):
            return x
        return x.at[:, slot].set(0)
    return jax.tree_util.tree_map_with_path(leaf, cache)
