"""Hessian-vector products and stochastic trace estimators (DESIGN §10).

Everything here is a pure pytree function: no flattening, no framework
state, so the same code runs under vmap (research trainer), pjit/shard_map
(launch/train.py — the jvp-of-grad inherits whatever sharding the params
carry), and inside the Lanczos iteration.

  hvp(loss, p, v)              = H(p) v            via forward-over-reverse
                                 (batch is baked into `loss`; see
                                 superbatch_loss_fn / make_hvp_fn)
  hutchinson_trace             ~ Tr(H)             Rademacher probes
  trace_hc                     = Tr(H C)           EXACT given the sample:
      C = (1/n) sum_j d_j d_j^T with d_j = w_j - w_a, so
      Tr(H C) = (1/n) sum_j d_j^T H d_j — the learner deviations ARE the
      probe vectors; no stochastic estimate needed.

Tr(H C) is the paper's coupling between local curvature H and the learner
weight covariance C: the quantity that makes DPSGD's noise *landscape
dependent* (Sec. 3), and the input to the Eq. 4 effective-LR predictor.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from ..core.util import learner_mean, tree_dot, tree_sub

__all__ = ["hvp", "make_hvp_fn", "superbatch_loss_fn", "hutchinson_trace",
           "trace_hc", "tree_rademacher_like"]


def superbatch_loss_fn(loss_fn: Callable, stacked_batch) -> Callable:
    """params -> mean over the n learner minibatches of loss_fn(params, b_j).

    The superbatch loss is the L whose Hessian the paper's analysis uses
    (gradients g and curvature H both evaluated at w_a over mu = U mu_j).
    """
    def f(params):
        return jnp.mean(jax.vmap(loss_fn, in_axes=(None, 0))(params,
                                                             stacked_batch))
    return f


def hvp(loss: Callable, params, vector):
    """H(params) @ vector for a scalar loss(params) — forward-over-reverse."""
    return jax.jvp(jax.grad(loss), (params,), (vector,))[1]


def make_hvp_fn(loss_fn: Callable, params, stacked_batch) -> Callable:
    """Closure v -> H v with H at ``params`` over the superbatch."""
    loss = superbatch_loss_fn(loss_fn, stacked_batch)

    def matvec(v):
        return hvp(loss, params, v)
    return matvec


def tree_rademacher_like(key, tree):
    """iid +-1 probe with the same structure/shapes as ``tree``."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    keys = jax.random.split(key, len(leaves))
    probes = [jax.random.rademacher(k, l.shape, jnp.float32)
              for k, l in zip(keys, leaves)]
    return jax.tree_util.tree_unflatten(treedef, probes)


def hutchinson_trace(loss_fn: Callable, params, stacked_batch, key,
                     n_samples: int = 8) -> jnp.ndarray:
    """Tr(H) ~ E_z[z^T H z], z Rademacher (unbiased; var 2||H_offdiag||_F^2)."""
    matvec = make_hvp_fn(loss_fn, params, stacked_batch)

    def one(k):
        z = tree_rademacher_like(k, params)
        return tree_dot(z, matvec(z))
    return jnp.mean(jax.vmap(one)(jax.random.split(key, n_samples)))


def trace_hc(loss_fn: Callable, stacked_params, stacked_batch) -> jnp.ndarray:
    """Tr(H C) = (1/n) sum_j d_j^T H d_j with H at w_a, d_j = w_j - w_a.

    Exact in the sample covariance (the d_j are the eigendirections the
    paper's C actually has); costs n HVPs.
    """
    w_a = learner_mean(stacked_params)
    matvec = make_hvp_fn(loss_fn, w_a, stacked_batch)

    def one(w_j):
        d = tree_sub(w_j, w_a)
        return tree_dot(d, matvec(d))
    return jnp.mean(jax.vmap(one)(stacked_params))
