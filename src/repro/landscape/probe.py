"""The landscape probe engine: schedule, measurement bundle, trainer hook.

A *probe* is an extra (scheduled, off-the-training-path) measurement pass
that looks at second-order structure: sharpness lambda_max via Lanczos,
Tr(H) via Hutchinson, Tr(H C) against the learner covariance, the gradient
noise scale, and the Eq. 4 predicted effective LR.  Probes are pure jitted
functions of (params, superbatch, key); the ProbeSchedule decides *when*
the host loop invokes them (the seam that replaced the ad-hoc ``diag_every``
logic — see MultiLearnerTrainer.add_probe / run_probes).

Cost per probe: 1 fwd/bwd (gradients) + (lanczos_iters + n learners +
hutchinson_samples) HVPs at ~2 fwd/bwd each.  At the default cadence
(every ~10-20 steps) this is a few percent of training time.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from ..core.util import learner_mean, learner_var, tree_norm_sq
from .hvp import hutchinson_trace, trace_hc
from .lanczos import lanczos_pytree, sharpness
from .predictor import predict_alpha_e

__all__ = ["ProbeSchedule", "ProbeResult", "probe_landscape",
           "make_probe_fn", "make_trainer_probe"]


@dataclasses.dataclass(frozen=True)
class ProbeSchedule:
    """When a probe fires: every ``every`` steps, starting at ``start``.

    ``every=0`` disables the probe.  Deliberately dumb (modular arithmetic on
    the host-visible step) so schedules compose with any training loop; the
    trainer only ever calls ``due(step)``.
    """
    every: int = 0
    start: int = 0

    def due(self, step: int) -> bool:
        return (self.every > 0 and step >= self.start
                and (step - self.start) % self.every == 0)


class ProbeResult(NamedTuple):
    """One landscape measurement (all scalars, f32)."""
    sharpness: jnp.ndarray      # lambda_max(H) at w_a (Lanczos)
    trace_h: jnp.ndarray        # Tr(H) (Hutchinson)
    trace_hc: jnp.ndarray       # Tr(H C) against the learner covariance
    sigma_w_sq: jnp.ndarray     # Tr(C) weight variance
    grad_norm: jnp.ndarray      # ||g|| at w_a over the superbatch
    gns: jnp.ndarray            # gradient noise scale: sigma_mb^2 / ||g||^2
    alpha_e_pred: jnp.ndarray   # Eq. 4 prediction (predictor.py)


def probe_landscape(loss_fn: Callable, params, stacked_batch, key, *,
                    alpha: float, lanczos_iters: int = 8,
                    hutchinson_samples: int = 4, stacked: bool = True,
                    reorth: str = "pallas") -> ProbeResult:
    """Measure the landscape at (the mean of) ``params`` over a superbatch.

    ``stacked=True``: params leaves carry a leading learner axis (n, ...) —
    the covariance terms (Tr(H C), sigma_w^2) are measured from the learner
    spread.  ``stacked=False``: a single replica (the pjit SSGD path) — the
    spread terms are identically 0 and alpha_e_pred == alpha.
    stacked_batch leaves are (n, B, ...) either way (the n superbatch shards
    double as the minibatch sample for the gradient noise scale).
    """
    if stacked:
        w_a = learner_mean(params)
        sig_sq = learner_var(params)
        t_hc = trace_hc(loss_fn, params, stacked_batch)
    else:
        w_a = params
        sig_sq = jnp.zeros((), jnp.float32)
        t_hc = jnp.zeros((), jnp.float32)

    # superbatch gradient + per-shard minibatch gradients at w_a
    g_shards = jax.vmap(jax.grad(loss_fn), in_axes=(None, 0))(w_a,
                                                              stacked_batch)
    g0 = learner_mean(g_shards)
    g_norm_sq = tree_norm_sq(g0)

    # gradient noise scale (unbiased minibatch-gradient variance over signal):
    # sigma_mb^2 = (1/(n-1)) sum_j ||g_j - g0||^2 ; gns = sigma_mb^2 / ||g||^2
    dev_sq = jax.vmap(lambda g_j: tree_norm_sq(
        jax.tree_util.tree_map(jnp.subtract, g_j, g0)))(g_shards)
    n = dev_sq.shape[0]
    gns = jnp.sum(dev_sq) / max(n - 1, 1) / jnp.maximum(g_norm_sq, 1e-30)

    k_lanczos, k_hutch = jax.random.split(key)
    lcz = lanczos_pytree(loss_fn, w_a, stacked_batch, m=lanczos_iters,
                         key=k_lanczos, reorth=reorth)
    t_h = hutchinson_trace(loss_fn, w_a, stacked_batch, k_hutch,
                           n_samples=hutchinson_samples)

    return ProbeResult(
        sharpness=sharpness(lcz),
        trace_h=t_h,
        trace_hc=t_hc,
        sigma_w_sq=sig_sq,
        grad_norm=jnp.sqrt(g_norm_sq),
        gns=gns,
        alpha_e_pred=predict_alpha_e(alpha, t_hc, sig_sq),
    )


def make_probe_fn(loss_fn: Callable, *, alpha: float, lanczos_iters: int = 8,
                  hutchinson_samples: int = 4, stacked: bool = True,
                  reorth: str = "pallas") -> Callable:
    """Jitted (params, stacked_batch, key) -> ProbeResult."""
    return jax.jit(partial(probe_landscape, loss_fn, alpha=alpha,
                           lanczos_iters=lanczos_iters,
                           hutchinson_samples=hutchinson_samples,
                           stacked=stacked, reorth=reorth))


def make_trainer_probe(loss_fn: Callable, *, alpha: float,
                       lanczos_iters: int = 8, hutchinson_samples: int = 4,
                       seed: int = 0, reorth: str = "pallas") -> Callable:
    """Probe in MultiLearnerTrainer hook shape: (state, stacked_batch) -> ProbeResult.

    The probe key is derived from the state's step so results are
    reproducible without threading RNG through the trainer.
    """
    core = make_probe_fn(loss_fn, alpha=alpha, lanczos_iters=lanczos_iters,
                         hutchinson_samples=hutchinson_samples, stacked=True,
                         reorth=reorth)

    def fn(state, stacked_batch):
        key = jax.random.fold_in(jax.random.PRNGKey(seed), state.step)
        return core(state.params, stacked_batch, key)
    return fn
