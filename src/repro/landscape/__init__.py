"""Landscape probe engine + closed-loop AutoLR controller (DESIGN §10).

Layers:
  hvp.py        Hessian-vector products, Hutchinson Tr(H), exact Tr(H C)
  lanczos.py    m-step Lanczos w/ Pallas-fused full reorthogonalization
  predictor.py  Eq. 4 effective-LR prediction from Tr(H C) / sigma_w^2
  probe.py      ProbeSchedule + ProbeResult + jitted probe functions
  autolr.py     AutoLRController: probe results -> clamped LR multiplier
"""
from .autolr import AutoLRController
from .hvp import (hutchinson_trace, hvp, make_hvp_fn, superbatch_loss_fn,
                  trace_hc, tree_rademacher_like)
from .lanczos import LanczosResult, lanczos, lanczos_pytree, sharpness
from .predictor import effective_curvature, predict_alpha_e
from .probe import (ProbeResult, ProbeSchedule, make_probe_fn,
                    make_trainer_probe, probe_landscape)

__all__ = [
    "AutoLRController", "hvp", "make_hvp_fn", "superbatch_loss_fn",
    "hutchinson_trace", "trace_hc", "tree_rademacher_like",
    "LanczosResult", "lanczos", "lanczos_pytree", "sharpness",
    "effective_curvature", "predict_alpha_e",
    "ProbeResult", "ProbeSchedule", "probe_landscape", "make_probe_fn",
    "make_trainer_probe",
]
