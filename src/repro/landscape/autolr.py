"""Closed-loop AutoLR: make SSGD's learning rate landscape-dependent.

The paper's observation is that DPSGD *implicitly* self-adjusts its
effective LR: gossip noise shrinks alpha_e on sharp terrain and restores it
as the landscape smooths.  The AutoLRController does the same thing
*explicitly* for plain SSGD, driven by the probe engine instead of by
gossip noise (AdaScale / DecentLaM measure related signals online;
DESIGN §10):

    control law (per probe, at base LR alpha0):
        s_ema  <- ema * s_ema + (1 - ema) * sharpness          (smoothed)
        raw    =  rho / (alpha0 * s_ema)        # target alpha*lambda = rho
        raw    /= 1 + gns_weight * gns          # optional noise backoff
        scale  =  clip(raw, min_scale, max_scale)

rho < 2 keeps the *effective* step inside the quadratic stability edge
(alpha * lambda_max < 2); on smooth terrain raw > max_scale and the clamp
returns the full base LR, i.e. the controller only intervenes where SSGD
would diverge — exactly the regime of paper Table 1 ("SSGD+AutoLR survives
the large-batch LRs where SSGD diverges", benchmarks/table1_large_batch.py).

The controller is deliberately host-side Python state (it runs at probe
cadence, between jitted steps); the jitted path reads the resulting scale
from the optimizer state via optim.scale_by_controller /
set_controller_scale, so one compiled train step serves every scale value.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from .probe import ProbeResult

__all__ = ["AutoLRController"]


@dataclasses.dataclass
class AutoLRController:
    """Probe results in, clamped LR multiplier out.

    alpha0:     the base learning rate the wrapped optimizer was built with.
    rho:        target alpha * lambda_max product (< 2, the stability edge).
    min_scale / max_scale: hard clamp on the emitted multiplier.
    ema:        sharpness smoothing (0 = trust each probe fully).
    gns_weight: optional backoff when the gradient noise scale is large
                (0 disables; noise-dominated probes then don't shrink LR).
    """
    alpha0: float
    rho: float = 1.8
    min_scale: float = 0.05
    max_scale: float = 1.0
    ema: float = 0.3
    gns_weight: float = 0.0

    scale: float = 1.0                      # last emitted multiplier
    sharpness_ema: Optional[float] = None   # smoothed lambda_max

    def __post_init__(self):
        assert 0.0 < self.rho < 2.0, "rho must sit inside the stability edge"
        assert 0.0 < self.min_scale <= self.max_scale, (self.min_scale,
                                                        self.max_scale)
        assert 0.0 <= self.ema < 1.0, self.ema

    def update(self, probe: ProbeResult) -> float:
        """Consume one probe, return the new LR multiplier in [min, max]."""
        s = float(probe.sharpness)
        if self.sharpness_ema is None or not (s == s):   # first probe / nan
            self.sharpness_ema = s if s == s else self.sharpness_ema
        else:
            self.sharpness_ema = (self.ema * self.sharpness_ema
                                  + (1.0 - self.ema) * s)
        if self.sharpness_ema is None or self.sharpness_ema <= 0.0:
            # flat or indefinite-direction-free probe: nothing to clamp on
            self.scale = self.max_scale
            return self.scale
        raw = self.rho / (self.alpha0 * self.sharpness_ema)
        if self.gns_weight:
            raw /= 1.0 + self.gns_weight * float(probe.gns)
        self.scale = min(max(raw, self.min_scale), self.max_scale)
        return self.scale
