"""m-step Lanczos on the HVP operator: top-k Hessian eigenvalues (sharpness).

The iteration lives on the (T, 128) flat parameter view (the same layout as
the gossip kernel, via kernels.gossip_mix.flatten_for_kernel) so the basis
is one stacked (m+1, T, 128) array and full reorthogonalization — the
memory-bound dot/axpy inner loop — runs through the fused Pallas kernels in
kernels/reorth.py (jnp oracle fallback: ``reorth='ref'``; used under
multi-device meshes where flattening would regather sharded params, see
launch/train.py and DESIGN §10).

Padding note: flatten_for_kernel zero-pads to a lane multiple.  The HVP
operator maps pad-zero vectors to pad-zero vectors (unflatten drops the pad,
flatten re-zeros it), and the start vector is generated as a pytree before
flattening, so the iteration never leaves the zero-pad subspace and the
spectrum is exactly that of H.

``m`` steps cost m HVPs + O(m^2) fused dot/axpys; eigenvalues come from the
dense (m, m) tridiagonal eigensolve (trivial at m ~ 8-32).  With full
reorthogonalization the extreme eigenvalues converge first — sharpness
(lambda_max, the AutoLR controller's input) is accurate to <<5% long before
the interior spectrum is.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from ..core.util import tree_gaussian_like
from ..kernels.gossip_mix import flatten_for_kernel
from ..kernels.ops import reorthogonalize

__all__ = ["LanczosResult", "lanczos", "lanczos_pytree", "sharpness"]


class LanczosResult(NamedTuple):
    eigenvalues: jnp.ndarray   # (m,) Ritz values, ascending
    alphas: jnp.ndarray        # (m,) tridiagonal diagonal
    betas: jnp.ndarray         # (m-1,) tridiagonal off-diagonal
    basis: jnp.ndarray         # (m+1, T, 128) Lanczos vectors (flat view)


def _tridiag_eigvals(alphas, betas):
    m = alphas.shape[0]
    t = (jnp.diag(alphas) + jnp.diag(betas, 1) + jnp.diag(betas, -1)
         if m > 1 else jnp.diag(alphas))
    return jnp.linalg.eigvalsh(t)


def lanczos(matvec_flat: Callable, q0, m: int, *,
            reorth: str = "pallas") -> LanczosResult:
    """m-step Lanczos for a symmetric operator on the (T, 128) flat view.

    matvec_flat: (T, 128) -> (T, 128); q0: start vector (need not be
    normalized).  Unrolled Python loop (m is static — call under jit).
    """
    T, lane = q0.shape
    eps = jnp.float32(1e-30)
    q0 = q0.astype(jnp.float32)
    q0 = q0 / jnp.maximum(jnp.sqrt(jnp.sum(q0 * q0)), eps)
    basis = jnp.zeros((m + 1, T, lane), jnp.float32).at[0].set(q0)

    alphas, betas = [], []
    for j in range(m):
        w = matvec_flat(basis[j]).astype(jnp.float32)
        alpha_j = jnp.sum(w * basis[j])
        alphas.append(alpha_j)
        # full reorthogonalization against ALL previous vectors (CGS2 through
        # the fused kernel) — subsumes the textbook alpha/beta subtraction
        mask = (jnp.arange(m + 1) <= j).astype(jnp.float32)
        w = reorthogonalize(basis, w, mask, backend=reorth)
        beta_j = jnp.sqrt(jnp.sum(w * w))
        if j < m - 1:
            betas.append(beta_j)
        # on breakdown (beta ~ 0: invariant subspace found) the normalized
        # vector is junk but its coupling beta is ~0, so Ritz values stand
        basis = basis.at[j + 1].set(w / jnp.maximum(beta_j, eps))

    alphas = jnp.stack(alphas)
    betas = jnp.stack(betas) if betas else jnp.zeros((0,), jnp.float32)
    return LanczosResult(_tridiag_eigvals(alphas, betas), alphas, betas, basis)


def lanczos_pytree(loss_fn_or_matvec, params, stacked_batch=None, *,
                   m: int = 8, key=None, reorth: str = "pallas",
                   matvec=None) -> LanczosResult:
    """Lanczos on the Hessian of the superbatch loss at ``params``.

    Either pass ``loss_fn_or_matvec`` = loss_fn(params, batch) together with
    ``stacked_batch`` (leaves (n, B, ...)), or a pytree operator via
    ``matvec=``.  ``key`` seeds the start vector (default PRNGKey(0)).
    """
    from .hvp import make_hvp_fn   # local import: hvp is kernel-free

    if matvec is None:
        matvec = make_hvp_fn(loss_fn_or_matvec, params, stacked_batch)
    if key is None:
        key = jax.random.PRNGKey(0)

    q0_tree = tree_gaussian_like(key, params, 1.0)
    q0, _ = flatten_for_kernel(q0_tree)
    _, unflatten = flatten_for_kernel(params)

    def matvec_flat(v_flat):
        hv = matvec(unflatten(v_flat))
        return flatten_for_kernel(hv)[0]

    return lanczos(matvec_flat, q0, m, reorth=reorth)


def sharpness(result: LanczosResult) -> jnp.ndarray:
    """lambda_max(H) — the stability-limiting curvature (alpha < 2/sharpness)."""
    return result.eigenvalues[-1]
