"""Eq. 4 effective-learning-rate prediction from measured curvature.

The paper *measures* alpha_e = alpha (g_a . g) / ||g||^2 (core/diagnostics).
This module *predicts* it from the probe quantities, closing the loop
between Sec. 3's analysis and the instrument:

    alpha_e ~= alpha * (1 - (alpha / 2) * Tr(H C) / sigma_w^2)        (Eq. 4)

Reading: Tr(H C) / Tr(C) is the covariance-weighted mean curvature h_eff —
the curvature the learner cloud actually *samples* (C weights each Hessian
direction by how much the learners spread along it; sigma_w^2 = Tr(C)).
alpha * (1 - (alpha/2) h_eff) is the standard quadratic-descent
renormalization of the step size at curvature h_eff: on rough terrain
(h_eff large) the predicted effective LR drops; as DPSGD smooths the
landscape it recovers — the self-adjustment mechanism, now falsifiable:
benchmarks/fig2_effective_lr.py overlays this prediction against the
measured alpha_e trajectory.

The prediction degrades exactly where the expansion does: once
alpha * h_eff > 2 (beyond the quadratic stability edge) or when sigma_w^2
~ 0 (SSGD: no learner spread, alpha_e == alpha by construction — we return
alpha there rather than 0/0).
"""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["effective_curvature", "predict_alpha_e"]


def effective_curvature(trace_hc, sigma_w_sq, eps: float = 1e-12):
    """h_eff = Tr(H C) / Tr(C); 0 when the learners have not spread (Tr C ~ 0)."""
    trace_hc = jnp.asarray(trace_hc, jnp.float32)
    sigma_w_sq = jnp.asarray(sigma_w_sq, jnp.float32)
    return jnp.where(sigma_w_sq > eps, trace_hc / jnp.maximum(sigma_w_sq, eps),
                     0.0)


def predict_alpha_e(alpha, trace_hc, sigma_w_sq, eps: float = 1e-12):
    """Paper Eq. 4: alpha_e ~= alpha (1 - (alpha/2) Tr(H C) / sigma_w^2)."""
    alpha = jnp.asarray(alpha, jnp.float32)
    return alpha * (1.0 - 0.5 * alpha
                    * effective_curvature(trace_hc, sigma_w_sq, eps))
