"""Shard-aware batch pipeline.

Each learner j consumes its OWN minibatch mu_j(t) (paper Sec. 2).  The loader
derives every batch deterministically from (seed, step, learner) so that:
  * no two learners ever see the same minibatch at the same step,
  * restarting from a checkpoint replays the identical stream,
  * the same code drives 1-device research runs and sharded production runs
    (the launcher simply device_puts each learner slice to its mesh group).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


def stack_learner_batches(sample_fn: Callable, key, n_learners: int, *args):
    """vmapped per-learner sampling -> leaves with leading (n_learners, ...)."""
    keys = jax.random.split(key, n_learners)
    return jax.vmap(lambda k: sample_fn(k, *args))(keys)


@dataclasses.dataclass
class ShardedLoader:
    dataset: object                 # must expose .sample(key, batch, *extra)
    n_learners: int
    local_batch: int
    extra_args: tuple = ()
    seed: int = 0

    def __post_init__(self):
        self._base = jax.random.PRNGKey(self.seed)
        sample = self.dataset.sample
        n = self.n_learners

        def _batch(step):
            key = jax.random.fold_in(self._base, step)
            keys = jax.random.split(key, n)
            return jax.vmap(
                lambda k: sample(k, self.local_batch, *self.extra_args))(keys)
        self._batch = jax.jit(_batch)

    def batch(self, step: int):
        """Stacked batch for all learners at `step`: leaves (n, B_local, ...)."""
        return self._batch(jnp.asarray(step, jnp.int32))

    def eval_batch(self, size: int, tag: int = 0x5EED):
        """A held-out batch (single, unstacked)."""
        key = jax.random.fold_in(self._base, tag)
        return self.dataset.sample(key, size, *self.extra_args)
