"""Synthetic datasets standing in for the paper's data gates (repro band 2/5).

The container ships no MNIST / CIFAR / ImageNet / SWB audio, so we generate
shape- and statistics-faithful stand-ins:

  * GaussianMixtureImages — K-class gaussian mixture in pixel space (28x28x1
    default = MNIST-like).  The paper's MNIST claims we reproduce are
    convergence-shape claims (diverge-vs-converge, alpha_e trajectories),
    which a separable-but-noisy mixture reproduces.
  * SyntheticTokenStream — autoregressive LM tokens from a random shallow
    markov teacher, uniform-ish marginals (CV/NLP proxy).
  * ZipfianTokenStream — 32k-class zipfian marginals mimicking the SWB ASR
    label skew the paper calls out (Sec. 4 footnote 3).
  * TeacherStudentRegression — clean landscape-control task for unit tests.

All are deterministic functions of (seed, index) — infinite, shardable,
resumable; no state on disk.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class GaussianMixtureImages:
    n_classes: int = 10
    height: int = 28
    width: int = 28
    channels: int = 1
    class_sep: float = 2.0      # distance between class means
    noise: float = 1.0
    seed: int = 0

    @property
    def dim(self):
        return self.height * self.width * self.channels

    def _means(self):
        key = jax.random.PRNGKey(self.seed)
        m = jax.random.normal(key, (self.n_classes, self.dim))
        return self.class_sep * m / jnp.linalg.norm(m, axis=1, keepdims=True)

    def sample(self, key, batch: int):
        """-> {'image': (B, H, W, C), 'label': (B,) int32}"""
        k1, k2 = jax.random.split(key)
        labels = jax.random.randint(k1, (batch,), 0, self.n_classes)
        means = self._means()[labels]
        x = means + self.noise * jax.random.normal(k2, (batch, self.dim))
        img = x.reshape(batch, self.height, self.width, self.channels)
        return {"image": img.astype(jnp.float32), "label": labels.astype(jnp.int32)}


@dataclasses.dataclass(frozen=True)
class SyntheticTokenStream:
    """LM batches from a fixed random bigram teacher: next-token logits are a
    (low-rank) function of the current token, so the task has learnable
    structure and a non-trivial loss floor."""
    vocab: int = 1024
    rank: int = 64
    temperature: float = 1.0
    seed: int = 0

    def _tables(self):
        key = jax.random.PRNGKey(self.seed)
        k1, k2 = jax.random.split(key)
        a = jax.random.normal(k1, (self.vocab, self.rank)) / np.sqrt(self.rank)
        b = jax.random.normal(k2, (self.rank, self.vocab)) / np.sqrt(self.rank)
        return a, b

    def sample(self, key, batch: int, seq_len: int):
        """-> {'tokens': (B, S) int32, 'labels': (B, S) int32}

        labels[t] = tokens[t+1]; the final label wraps to token 0 and is
        masked downstream via 'mask'.
        """
        a, b = self._tables()

        def step(tok, k):
            logits = (a[tok] @ b) / self.temperature
            nxt = jax.random.categorical(k, logits)
            return nxt, nxt

        k0, kseq = jax.random.split(key)
        first = jax.random.randint(k0, (batch,), 0, self.vocab)
        keys = jax.random.split(kseq, seq_len)
        _, toks = jax.lax.scan(step, first, keys)
        toks = jnp.concatenate([first[None], toks], axis=0).T  # (B, S+1)
        return {"tokens": toks[:, :-1].astype(jnp.int32),
                "labels": toks[:, 1:].astype(jnp.int32),
                "mask": jnp.ones((batch, seq_len), jnp.float32)}


@dataclasses.dataclass(frozen=True)
class ZipfianTokenStream:
    """Highly uneven class marginals (the ASR stress case): p(c) ~ 1/(c+1)^a."""
    vocab: int = 32000
    alpha: float = 1.2
    seed: int = 0

    def sample(self, key, batch: int, seq_len: int):
        ranks = jnp.arange(1, self.vocab + 1, dtype=jnp.float32)
        logp = -self.alpha * jnp.log(ranks)
        toks = jax.random.categorical(
            key, jnp.broadcast_to(logp, (batch, seq_len + 1, self.vocab)))
        return {"tokens": toks[:, :-1].astype(jnp.int32),
                "labels": toks[:, 1:].astype(jnp.int32),
                "mask": jnp.ones((batch, seq_len), jnp.float32)}


@dataclasses.dataclass(frozen=True)
class TemplateImages:
    """MNIST-faithful stand-in: *uncentered* [0,1] pixels with sparse class
    templates.  The non-centered input statistics give the loss landscape the
    dominant curvature direction real MNIST has — this is the regime where
    the paper's Fig. 2a separation (SSGD oscillates/diverges at large lr,
    DPSGD converges) actually reproduces; whitened gaussian mixtures do NOT
    reproduce it (see EXPERIMENTS.md §Fig2)."""
    n_classes: int = 10
    dim: int = 784
    template_density: float = 0.2
    base: float = 0.2
    noise: float = 0.2
    signal: float = 0.8
    seed: int = 5

    def _templates(self):
        key = jax.random.PRNGKey(self.seed)
        return (jax.random.uniform(key, (self.n_classes, self.dim))
                > 1.0 - self.template_density).astype(jnp.float32)

    def sample(self, key, batch: int):
        k1, k2 = jax.random.split(key)
        lab = jax.random.randint(k1, (batch,), 0, self.n_classes)
        x = jnp.clip(self.base + self.noise * jax.random.normal(
            k2, (batch, self.dim)) + self.signal * self._templates()[lab],
            0.0, 1.0)
        return {"image": x.reshape(batch, 28, 28, 1) if self.dim == 784
                else x,
                "label": lab.astype(jnp.int32)}


@dataclasses.dataclass(frozen=True)
class TeacherStudentRegression:
    dim: int = 32
    teacher_scale: float = 1.0
    noise: float = 0.01
    seed: int = 0

    def teacher(self):
        key = jax.random.PRNGKey(self.seed)
        return self.teacher_scale * jax.random.normal(key, (self.dim, 1))

    def sample(self, key, batch: int):
        k1, k2 = jax.random.split(key)
        x = jax.random.normal(k1, (batch, self.dim))
        y = x @ self.teacher() + self.noise * jax.random.normal(k2, (batch, 1))
        return {"x": x, "y": y}
