from .pipeline import ShardedLoader, stack_learner_batches
from .synthetic import (GaussianMixtureImages, SyntheticTokenStream,
                        TeacherStudentRegression, TemplateImages,
                        ZipfianTokenStream)

__all__ = ["GaussianMixtureImages", "SyntheticTokenStream", "TemplateImages", "ZipfianTokenStream",
           "TeacherStudentRegression", "ShardedLoader", "stack_learner_batches"]
