from .synthetic import (GaussianMixtureImages, SyntheticTokenStream,
                        TemplateImages, ZipfianTokenStream,
                        TeacherStudentRegression)
from .pipeline import ShardedLoader, stack_learner_batches

__all__ = ["GaussianMixtureImages", "SyntheticTokenStream", "TemplateImages", "ZipfianTokenStream",
           "TeacherStudentRegression", "ShardedLoader", "stack_learner_batches"]
