"""Learning-rate schedules used by the paper's recipes."""
from __future__ import annotations

import jax.numpy as jnp


def constant_schedule(value: float = 1.0):
    return lambda step: jnp.asarray(value, jnp.float32)


def linear_warmup(warmup_steps: int, peak: float = 1.0, base: float = 0.0):
    def f(step):
        s = step.astype(jnp.float32) if hasattr(step, "astype") else jnp.float32(step)
        frac = jnp.minimum(s / max(warmup_steps, 1), 1.0)
        return base + (peak - base) * frac
    return f


def step_decay(boundaries, values):
    """Piecewise-constant: the paper's CIFAR schedule (0.1 / 0.01 / 0.001)."""
    bs = jnp.asarray(boundaries)
    vs = jnp.asarray(values, jnp.float32)

    def f(step):
        idx = jnp.sum(step >= bs)
        return vs[idx]
    return f


def warmup_linear_scale(warmup_steps: int, scale: float,
                        anneal_boundaries=(), anneal_factor: float = 0.1):
    """Goyal et al. large-batch recipe: warm up from 1x to `scale`x over
    warmup_steps, then multiply by anneal_factor at each boundary."""
    bs = jnp.asarray(anneal_boundaries) if len(anneal_boundaries) else None

    def f(step):
        s = step.astype(jnp.float32) if hasattr(step, "astype") else jnp.float32(step)
        warm = 1.0 + (scale - 1.0) * jnp.minimum(s / max(warmup_steps, 1), 1.0)
        if bs is not None:
            warm = warm * anneal_factor ** jnp.sum(step >= bs)
        return warm
    return f
