"""Learning-rate schedules used by the paper's recipes, plus the
controller-driven scale adapter for the closed-loop AutoLR path."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .base import FusedSGD, Optimizer


def constant_schedule(value: float = 1.0):
    return lambda step: jnp.asarray(value, jnp.float32)


def linear_warmup(warmup_steps: int, peak: float = 1.0, base: float = 0.0):
    def f(step):
        s = step.astype(jnp.float32) if hasattr(step, "astype") else jnp.float32(step)
        frac = jnp.minimum(s / max(warmup_steps, 1), 1.0)
        return base + (peak - base) * frac
    return f


def step_decay(boundaries, values):
    """Piecewise-constant: the paper's CIFAR schedule (0.1 / 0.01 / 0.001)."""
    bs = jnp.asarray(boundaries)
    vs = jnp.asarray(values, jnp.float32)

    def f(step):
        idx = jnp.sum(step >= bs)
        return vs[idx]
    return f


def scale_by_controller(opt: Optimizer) -> Optimizer:
    """Wrap an optimizer so its updates are multiplied by a *mutable* scale.

    Schedules are pure functions of the step; a controller (e.g.
    landscape.AutoLRController) is host-side state that changes at probe
    cadence.  The scale therefore lives in the optimizer state where the
    jitted step can read it, and the host writes it between steps with
    ``set_controller_scale`` — one compiled train step serves every scale
    value (no retrace).  Composes with scale_by_schedule (wrap either way).
    """
    def init(params):
        return {"inner": opt.init(params), "scale": jnp.ones((), jnp.float32)}

    def update(grads, state, params, *extra):
        upd, inner = opt.update(grads, state["inner"], params, *extra)
        upd = jax.tree_util.tree_map(lambda u: state["scale"] * u, upd)
        return upd, {"inner": inner, "scale": state["scale"]}

    fused = None
    if opt.fused is not None:
        f = opt.fused
        fused = FusedSGD(
            lr=f.lr, beta=f.beta, weight_decay=f.weight_decay,
            read_mu=lambda s: f.read_mu(s["inner"]),
            write_mu=lambda s, mu: {**s, "inner": f.write_mu(s["inner"], mu)},
            scale=lambda s: s["scale"] * f.scale(s["inner"]),
            bump=lambda s: {**s, "inner": f.bump(s["inner"])})
    return Optimizer(init, update, wants_mixed=opt.wants_mixed, fused=fused,
                     layout_sensitive=opt.layout_sensitive,
                     static_mixing_only=opt.static_mixing_only)


def set_controller_scale(opt_state, scale):
    """Functionally write the controller's multiplier into a (possibly
    vmapped/stacked) scale_by_controller state.

    Descends through ``"inner"`` wrappers so it finds the controller layer
    regardless of wrap order (e.g. scale_by_schedule around
    scale_by_controller or vice versa)."""
    if "scale" in opt_state:
        s = opt_state["scale"]
        new = jnp.broadcast_to(jnp.asarray(scale, s.dtype), s.shape)
        return {**opt_state, "scale": new}
    if "inner" in opt_state:
        return {**opt_state,
                "inner": set_controller_scale(opt_state["inner"], scale)}
    raise KeyError("no scale_by_controller layer in this optimizer state")


def controller_scale(opt_state) -> jnp.ndarray:
    """Read back the current multiplier (stacked states return (n,));
    descends through ``"inner"`` wrappers like set_controller_scale."""
    if "scale" in opt_state:
        return opt_state["scale"]
    if "inner" in opt_state:
        return controller_scale(opt_state["inner"])
    raise KeyError("no scale_by_controller layer in this optimizer state")


def warmup_linear_scale(warmup_steps: int, scale: float,
                        anneal_boundaries=(), anneal_factor: float = 0.1):
    """Goyal et al. large-batch recipe: warm up from 1x to `scale`x over
    warmup_steps, then multiply by anneal_factor at each boundary."""
    bs = jnp.asarray(anneal_boundaries) if len(anneal_boundaries) else None

    def f(step):
        s = step.astype(jnp.float32) if hasattr(step, "astype") else jnp.float32(step)
        warm = 1.0 + (scale - 1.0) * jnp.minimum(s / max(warmup_steps, 1), 1.0)
        if bs is not None:
            warm = warm * anneal_factor ** jnp.sum(step >= bs)
        return warm
    return f
