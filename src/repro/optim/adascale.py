"""AdaScale gain-ratio LR rule, stacked under the AutoLR stability clamp.

An elastic fleet changes its effective batch size every time membership
changes: n_active learners contribute gradients, so the linear-scaling
heuristic would jump the LR by n_active — and overshoot exactly when the
loss landscape can't take it.  AdaScale (Johnson et al., 2020) replaces
the heuristic with a measured *gain ratio*

    r = (sigma^2 + mu^2) / (sigma^2 / n + mu^2)   in [1, n],

where mu^2 = |E g|^2 is the squared mean-gradient norm and sigma^2 the
total per-learner gradient variance: when learner gradients agree
(mu^2 >> sigma^2) averaging buys nothing and r -> 1; when they are noise
(sigma^2 >> mu^2) averaging over n buys the full r -> n.  Both moments
come free from the trainer's per-step metrics (``grad_sq_mean`` = mean_i
|g_i|^2 and ``grad_norm`` = |mean_i g_i| over the ACTIVE learners) and
are EMA-smoothed.

:class:`AdaScaleAutoLR` composes the gain with the paper's closed-loop
AutoLR controller through the same ``scale_by_controller`` seam: the
emitted multiplier is ``min(gain * autolr_scale, rho / (alpha0 *
sharpness_ema))`` — the AdaScale gain proposes, the curvature clamp
disposes, so ``alpha_eff * lambda_max <= rho < 2`` holds across resizes
by construction (DESIGN §15).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

__all__ = ["AdaScale", "AdaScaleAutoLR"]


@dataclasses.dataclass
class AdaScale:
    """Host-side gain-ratio estimator (per-step ``update``, like the
    AutoLR controller's per-probe one).

    theta: EMA retention for the two moment estimates (0 = trust each
    step fully); eps guards the denominator at exact consensus.
    """
    theta: float = 0.9
    eps: float = 1e-12

    sigma_sq: Optional[float] = None    # EMA'd total gradient variance
    mu_sq: Optional[float] = None       # EMA'd squared mean-grad norm
    gain: float = 1.0                   # last emitted ratio

    def __post_init__(self):
        assert 0.0 <= self.theta < 1.0, self.theta

    def update(self, grad_sq_mean: float, grad_norm_sq: float,
               n_active: float) -> float:
        """Consume one step's gradient moments; return the gain in [1, n].

        ``grad_sq_mean`` = mean_i |g_i|^2, ``grad_norm_sq`` = |mean_i g_i|^2
        over the n_active live learners (the trainer's masked metrics).
        """
        n = max(float(n_active), 1.0)
        m2, mb = float(grad_sq_mean), float(grad_norm_sq)
        if not (m2 == m2 and mb == mb):        # NaN probe: hold the gain
            return self.gain
        if n <= 1.0:
            self.gain = 1.0
            return self.gain
        # unbiased moment split: E|g_i|^2 = mu^2 + sigma^2 and
        # E|gbar|^2 = mu^2 + sigma^2/n  =>  solve for (sigma^2, mu^2)
        var = max(m2 - mb, 0.0) * n / (n - 1.0)
        mu = max(mb - var / n, 0.0)
        if self.sigma_sq is None:
            self.sigma_sq, self.mu_sq = var, mu
        else:
            t = self.theta
            self.sigma_sq = t * self.sigma_sq + (1.0 - t) * var
            self.mu_sq = t * self.mu_sq + (1.0 - t) * mu
        r = ((self.sigma_sq + self.mu_sq)
             / (self.sigma_sq / n + self.mu_sq + self.eps))
        self.gain = min(max(r, 1.0), n)
        return self.gain

    def reset_smoothing(self) -> None:
        """Drop the EMA state (call on a resize if the noise regime moved)."""
        self.sigma_sq = self.mu_sq = None


@dataclasses.dataclass
class AdaScaleAutoLR:
    """AdaScale gain stacked UNDER the AutoLR stability clamp.

    ``autolr`` is duck-typed (landscape.AutoLRController or anything with
    ``update(probe)``, ``scale``, ``alpha0``, ``rho``, ``sharpness_ema``,
    ``max_scale``): feed probes to :meth:`on_probe` at probe cadence and
    step metrics to :meth:`on_metrics` every step; write :attr:`scale`
    into the optimizer state with ``set_controller_scale``.
    """
    autolr: Any
    adascale: AdaScale = dataclasses.field(default_factory=AdaScale)
    max_gain: Optional[float] = None    # optional hard cap on the gain

    scale: float = 1.0                  # last composed multiplier

    def on_metrics(self, metrics) -> float:
        """Per-step: fold the fresh gradient moments into the gain.
        ``metrics`` is a trainer StepMetrics (host-fetched)."""
        gn = float(metrics.grad_norm)
        self.adascale.update(float(metrics.grad_sq_mean), gn * gn,
                             float(metrics.n_active))
        return self._compose()

    def on_probe(self, probe) -> float:
        """Probe cadence: refresh the curvature clamp, recompose."""
        self.autolr.update(probe)
        return self._compose()

    def _compose(self) -> float:
        gain = self.adascale.gain
        if self.max_gain is not None:
            gain = min(gain, self.max_gain)
        scale = gain * float(self.autolr.scale)
        # the stability edge binds LAST: alpha0 * scale * lambda <= rho
        ema = self.autolr.sharpness_ema
        if ema is not None and ema > 0.0:
            scale = min(scale, self.autolr.rho / (self.autolr.alpha0 * ema))
        self.scale = max(scale, 0.0)
        return self.scale
