from __future__ import annotations

import jax
import jax.numpy as jnp

from .base import Optimizer


def lamb(lr: float, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-6,
         weight_decay: float = 0.01) -> Optimizer:
    """LAMB (You et al. 2019) — the paper's SSGD large-batch baseline (Fig. 3).

    Layer-wise trust ratio: r = ||p|| / ||adam_step|| per leaf.
    """

    def init(params):
        z = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        return {"m": jax.tree_util.tree_map(z, params),
                "v": jax.tree_util.tree_map(z, params),
                "t": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        t = state["t"] + 1
        m = jax.tree_util.tree_map(
            lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
            state["m"], grads)
        v = jax.tree_util.tree_map(
            lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state["v"], grads)
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        bc2 = 1 - b2 ** t.astype(jnp.float32)

        def _upd(m_, v_, p):
            u = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
            u = u + weight_decay * p.astype(jnp.float32)
            pn = jnp.linalg.norm(p.astype(jnp.float32).ravel())
            un = jnp.linalg.norm(u.ravel())
            trust = jnp.where((pn > 0) & (un > 0), pn / un, 1.0)
            return -lr * trust * u
        upd = jax.tree_util.tree_map(_upd, m, v, params)
        return upd, {"m": m, "v": v, "t": t}

    # layer-wise trust ratio: semantics depend on the leaf structure —
    # the flat engine must not run it on a single collapsed leaf
    return Optimizer(init, update, layout_sensitive=True)
