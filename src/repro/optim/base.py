from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class FusedSGD:
    """Static recipe for the fused flat-engine update (DESIGN §11).

    An optimizer that is exactly momentum-SGD (optionally weight-decayed and
    scaled by schedule/controller multipliers) can run inside the batched
    gossip-mix Pallas kernel instead of as separate tree_map passes.  The
    kernel bakes in ``lr``/``beta``/``weight_decay`` statically; everything
    state-dependent flows through these accessors so wrappers
    (scale_by_schedule, scale_by_controller) compose without retracing:

      read_mu / write_mu — locate the momentum buffer inside the (possibly
        nested) optimizer state; read_mu returns None for momentum-free SGD.
      scale — the traced lr multiplier ((n,) for vmapped/stacked states,
        scalar otherwise); the kernel receives it as an operand.
      bump — advance any step counters (the momentum write is separate).
    """
    lr: float
    beta: float = 0.0
    weight_decay: float = 0.0
    read_mu: Callable[[Any], Any] = lambda s: None
    write_mu: Callable[[Any, Any], Any] = lambda s, mu: s
    scale: Callable[[Any], Any] = lambda s: jnp.float32(1.0)
    bump: Callable[[Any], Any] = lambda s: s


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[..., Any]  # (grads, state, params) -> (updates, state)
    # decentralized-aware optimizers (decentlam) additionally receive the
    # post-gossip weights: update(grads, state, params, mixed)
    wants_mixed: bool = False
    # non-None when the update is plain (momentum-)SGD and may be fused into
    # the flat engine's batched gossip kernel (core/trainer.py, DESIGN §11)
    fused: Optional[FusedSGD] = None
    # True when the update's semantics depend on the per-leaf structure
    # (lamb's layer-wise trust ratio): the flat engine would silently
    # collapse that to one global leaf, so the trainer refuses/avoids it
    layout_sensitive: bool = False
    # True when the update is only stable under a STATIC mixing matrix
    # (decentlam's exact drift correction, drift_scale > 1 - momentum):
    # pairing it with a time-varying GossipSchedule (random matchings,
    # one-peer exponential) silently diverges, so the trainer and the pjit
    # step builders raise instead (see optim/decentlam.py)
    static_mixing_only: bool = False


def apply_updates(params, updates):
    return jax.tree_util.tree_map(
        lambda p, u: (p + u.astype(p.dtype)) if u is not None else p,
        params, updates)


def scale_by_schedule(opt: Optimizer, schedule) -> Optimizer:
    """Wrap an optimizer so its lr is multiplied by schedule(step).

    State grows a step counter.
    """
    def init(params):
        return {"inner": opt.init(params), "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, *extra):
        scale = schedule(state["step"])
        upd, inner = opt.update(grads, state["inner"], params, *extra)
        upd = jax.tree_util.tree_map(lambda u: scale * u, upd)
        return upd, {"inner": inner, "step": state["step"] + 1}

    fused = None
    if opt.fused is not None:
        f = opt.fused
        fused = FusedSGD(
            lr=f.lr, beta=f.beta, weight_decay=f.weight_decay,
            read_mu=lambda s: f.read_mu(s["inner"]),
            write_mu=lambda s, mu: {**s, "inner": f.write_mu(s["inner"], mu)},
            scale=lambda s: schedule(s["step"]) * f.scale(s["inner"]),
            bump=lambda s: {**s, "inner": f.bump(s["inner"]),
                            "step": s["step"] + 1})
    return Optimizer(init, update, wants_mixed=opt.wants_mixed, fused=fused,
                     layout_sensitive=opt.layout_sensitive,
                     static_mixing_only=opt.static_mixing_only)
