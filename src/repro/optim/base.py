from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[..., Any]  # (grads, state, params) -> (updates, state)
    # decentralized-aware optimizers (decentlam) additionally receive the
    # post-gossip weights: update(grads, state, params, mixed)
    wants_mixed: bool = False


def apply_updates(params, updates):
    return jax.tree_util.tree_map(
        lambda p, u: (p + u.astype(p.dtype)) if u is not None else p,
        params, updates)


def scale_by_schedule(opt: Optimizer, schedule) -> Optimizer:
    """Wrap an optimizer so its lr is multiplied by schedule(step).

    State grows a step counter.
    """
    def init(params):
        return {"inner": opt.init(params), "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, *extra):
        scale = schedule(state["step"])
        upd, inner = opt.update(grads, state["inner"], params, *extra)
        upd = jax.tree_util.tree_map(lambda u: scale * u, upd)
        return upd, {"inner": inner, "step": state["step"] + 1}

    return Optimizer(init, update, wants_mixed=opt.wants_mixed)
