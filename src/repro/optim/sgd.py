from __future__ import annotations

import jax
import jax.numpy as jnp

from .base import Optimizer


def sgd(lr: float, momentum: float = 0.0, nesterov: bool = False,
        weight_decay: float = 0.0) -> Optimizer:
    """SGD with (optional) heavy-ball momentum — the paper's optimizer."""

    def init(params):
        if momentum == 0.0:
            return ()
        return {"mu": jax.tree_util.tree_map(
            lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)}

    def update(grads, state, params):
        if weight_decay:
            grads = jax.tree_util.tree_map(
                lambda g, p: g + weight_decay * p.astype(g.dtype), grads, params)
        if momentum == 0.0:
            upd = jax.tree_util.tree_map(lambda g: -lr * g, grads)
            return upd, state
        mu = jax.tree_util.tree_map(
            lambda m, g: momentum * m + g.astype(jnp.float32), state["mu"], grads)
        if nesterov:
            upd = jax.tree_util.tree_map(
                lambda m, g: -lr * (momentum * m + g.astype(jnp.float32)), mu, grads)
        else:
            upd = jax.tree_util.tree_map(lambda m: -lr * m, mu)
        return upd, {"mu": mu}

    return Optimizer(init, update)
