from __future__ import annotations

import jax
import jax.numpy as jnp

from .base import FusedSGD, Optimizer


def sgd(lr: float, momentum: float = 0.0, nesterov: bool = False,
        weight_decay: float = 0.0) -> Optimizer:
    """SGD with (optional) heavy-ball momentum — the paper's optimizer.

    Heavy-ball (and plain) SGD advertises a FusedSGD recipe so the flat
    engine can run it inside the batched gossip kernel; the nesterov
    variant's update reads both mu and g after the accumulate and stays on
    the unfused path.
    """

    def init(params):
        if momentum == 0.0:
            return ()
        return {"mu": jax.tree_util.tree_map(
            lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)}

    def update(grads, state, params):
        if weight_decay:
            grads = jax.tree_util.tree_map(
                lambda g, p: g + weight_decay * p.astype(g.dtype), grads, params)
        if momentum == 0.0:
            upd = jax.tree_util.tree_map(lambda g: -lr * g, grads)
            return upd, state
        mu = jax.tree_util.tree_map(
            lambda m, g: momentum * m + g.astype(jnp.float32), state["mu"], grads)
        if nesterov:
            upd = jax.tree_util.tree_map(
                lambda m, g: -lr * (momentum * m + g.astype(jnp.float32)), mu, grads)
        else:
            upd = jax.tree_util.tree_map(lambda m: -lr * m, mu)
        return upd, {"mu": mu}

    fused = None
    if not nesterov:
        if momentum == 0.0:
            fused = FusedSGD(lr=lr, weight_decay=weight_decay)
        else:
            fused = FusedSGD(lr=lr, beta=momentum, weight_decay=weight_decay,
                             read_mu=lambda s: s["mu"],
                             write_mu=lambda s, mu_new: {"mu": mu_new})
    return Optimizer(init, update, fused=fused)
