"""DecentLaM (Yuan et al. 2021, arXiv:2104.11981): momentum-corrected
decentralized SGD for large-batch training.

Naive decentralized momentum (each learner runs heavy-ball locally and
gossips, "DmSGD") biases the consensus fixed point: the momentum buffer
repeatedly re-accumulates the gossip displacement, adding an
O(lr * beta / (1 - beta)) data-heterogeneity bias that grows exactly in the
large-batch regime this repo targets.  DecentLaM folds the consensus drift
into the quantity the momentum buffer accumulates:

    d_j = g_j + (w_j - mix(w)_j) / lr        # corrected gradient
    m_j = beta * m_j + d_j
    w_j <- w_j - lr * m_j

Expanding the last line shows the update relative to the *mixed* weights:

    w_j <- mix(w)_j - lr * (beta * m_j_prev + g_j)

which is the form implemented here so it composes with the trainer's
"mix then descend" ordering (update applied on top of the gossip average,
exactly like the other optimizers):

    updates = -lr * (beta * m_prev + g)       # applied to mix(w)
    m_new   = beta * m_prev + g + (w - mix(w)) / lr

With no gossip (mix(w) == w, e.g. the 'solo' topology or the SSGD path) the
drift vanishes and DecentLaM is bitwise heavy-ball SGD (asserted in tests).

Static vs time-varying topologies: the exact correction (drift_scale=1.0)
assumes the paper's *static* mixing matrix — the momentum buffer keeps
re-applying a correction of total size beta/(1-beta) x the pair difference,
which a fixed W absorbs (the linearized system is stable for beta < 1) but
randomly re-drawn pairings amplify (measured: divergence on random_pair at
beta=0.9).  For time-varying matchings (topology='random_pair', AD-PSGD)
pass ``drift_scale=1 - momentum``: the geometric series then sums to exactly
ONE consensus displacement per injected drift, which is stable under
switching and still removes most of the naive-momentum bias (see
tests/test_adpsgd.py).  Since the GossipSchedule engine (DESIGN §12) this
is enforced: an exact-drift DecentLaM marks itself ``static_mixing_only``
and the trainer raises when the compiled schedule is time-varying, instead
of letting the run silently diverge.

Note: the drift term divides by the base lr, so wrap with schedules only if
the schedule is constant — a time-varying scale would use a different lr in
the multiply than in the divide.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .base import Optimizer


def decentlam(lr: float, momentum: float = 0.9, weight_decay: float = 0.0,
              drift_scale: float = 1.0,
              unsafe_switching: bool = False) -> Optimizer:
    """Momentum-corrected decentralized SGD (DecentLaM).

    The returned optimizer has ``wants_mixed=True``: its update takes a 4th
    argument, the post-gossip weights, and the trainer applies the returned
    updates to those mixed weights.

    ``drift_scale=1.0`` is the paper-exact correction (static topologies);
    use ``1 - momentum`` with time-varying pairwise gossip (random_pair /
    AD-PSGD) — see the module docstring.  A drift scale above the stable
    ``1 - momentum`` threshold marks the optimizer ``static_mixing_only``,
    and the trainer / pjit step builders REFUSE to pair it with a
    time-varying GossipSchedule instead of silently diverging (the PR 1
    failure mode).  ``unsafe_switching=True`` drops that guard — only for
    deliberately demonstrating the divergence.
    """
    assert lr > 0.0, lr
    assert 0.0 <= drift_scale <= 1.0, drift_scale
    static_only = (drift_scale > (1.0 - momentum) + 1e-9
                   and not unsafe_switching)

    def init(params):
        return {"mu": jax.tree_util.tree_map(
            lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)}

    def update(grads, state, params, mixed=None):
        if weight_decay:
            grads = jax.tree_util.tree_map(
                lambda g, p: g + weight_decay * p.astype(g.dtype), grads, params)
        upd = jax.tree_util.tree_map(
            lambda m, g: -lr * (momentum * m + g.astype(jnp.float32)),
            state["mu"], grads)
        if mixed is None:          # degenerate: no gossip this step
            mixed = params
        drift = jax.tree_util.tree_map(
            lambda w, s: drift_scale
            * (w.astype(jnp.float32) - s.astype(jnp.float32)) / lr,
            params, mixed)
        mu = jax.tree_util.tree_map(
            lambda m, g, d: momentum * m + g.astype(jnp.float32) + d,
            state["mu"], grads, drift)
        return upd, {"mu": mu}

    return Optimizer(init, update, wants_mixed=True,
                     static_mixing_only=static_only)
