"""Pure-JAX optimizers (optax-style (init, update) pairs, built from scratch).

An optimizer is a pair of functions:
    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
    new_params = apply_updates(params, updates)   # params + updates

`updates` already includes the (negative) learning-rate scaling, so
apply_updates is a plain tree add.  All of them are learner-axis agnostic:
stacking a leading learner dim on every leaf just works.
"""
from .adam import adam
from .adascale import AdaScale, AdaScaleAutoLR
from .base import FusedSGD, Optimizer, apply_updates, scale_by_schedule
from .decentlam import decentlam
from .lamb import lamb
from .schedules import (constant_schedule, controller_scale, linear_warmup,
                        scale_by_controller, set_controller_scale, step_decay,
                        warmup_linear_scale)
from .sgd import sgd

__all__ = ["FusedSGD", "Optimizer", "apply_updates", "sgd", "adam", "lamb",
           "decentlam", "AdaScale", "AdaScaleAutoLR",
           "constant_schedule", "linear_warmup", "step_decay",
           "warmup_linear_scale", "scale_by_schedule", "scale_by_controller",
           "set_controller_scale", "controller_scale"]
