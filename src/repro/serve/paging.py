"""Host-side page allocator for the paged KV cache (DESIGN §14).

The device side is dumb on purpose: per attention layer a
(n_pages, page_size, KV, hd) pool plus a (n_slots, max_pages) int32 page
table passed into every jitted decode step.  All ownership bookkeeping —
which physical pages a slot holds, which are free — lives here on the host,
where it costs a few list ops per admitted/evicted request instead of a
retrace.

Page 0 is reserved as the SCRATCH page: a free (or page-stalled) slot's
table entries stay 0, so its masked write in the fused step lands there and
is never read back (the per-slot length masks exclude it).  The allocator
therefore only ever hands out pages 1..n_pages-1.
"""
from __future__ import annotations


class OutOfPages(RuntimeError):
    """No free page in the pool (the caller should stall, not crash)."""


class PageAllocator:
    def __init__(self, n_pages: int):
        assert n_pages >= 2, "need at least one real page beyond scratch"
        self.n_pages = n_pages
        # LIFO free list: recently-freed (cache-hot) pages are reused first
        self._free = list(range(n_pages - 1, 0, -1))

    @property
    def free_pages(self) -> int:
        return len(self._free)

    def alloc(self) -> int:
        if not self._free:
            raise OutOfPages(f"pool of {self.n_pages - 1} pages exhausted")
        return self._free.pop()

    def free(self, pages) -> None:
        for p in pages:
            assert 0 < p < self.n_pages, p
            self._free.append(int(p))
