"""Continuous-batching serve engine over the paged decode path (ISSUE 7).

One jitted ``paged_decode_step`` serves a fixed grid of ``n_slots`` decode
slots; everything dynamic — admission, prefill progress, sampling, EOS/
max-token eviction, page allocation — happens on the host between steps, so
new requests join a RUNNING batch without retracing (the shapes never
change).  Prefill rides the decode path one token per step ("chunked
prefill" with chunk=1): a slot still consuming its prompt feeds the next
prompt token instead of a sampled one and its logits are ignored until the
prompt is exhausted, which is what lets prefill and decode mix freely in
the same batch.

Slot lifecycle:  FREE -> (admit) -> PREFILL -> DECODE -> (EOS | max-tokens)
-> evict -> FREE.  Eviction returns the slot's pages to the allocator,
zeroes its page-table row (pointing it back at the scratch page) and resets
any recurrent per-slot cache state (``api.reset_slot``); the pages' stale
contents are never read because length masks exclude them — recycling costs
zero device work beyond that reset.

Admission policies:
  * ``continuous`` — a request is admitted the moment a slot is free (the
    tentpole path);
  * ``static`` — the serve_batched.py baseline: admit a full batch only
    when EVERY slot is free, then run it to completion (head-of-line
    blocking: early finishers idle until the longest request drains).  The
    benchmark pits the two against the same Poisson arrival stream.

If the page pool runs dry mid-flight the affected slot STALLS: it is not
advanced (its token is re-fed next step), its masked write lands in the
scratch page, and it resumes as soon as an eviction frees pages.  Slots
that must not make progress this step — FREE slots and page-stalled ones —
are excluded from the ``advance`` mask passed to ``paged_decode_step``, so
their recurrent per-slot state (mamba conv/ssm, xLSTM C/n/m) stays bitwise
frozen; the scratch page covers only the attention K/V write.  As a second
line of defense ``reset_slot`` runs at admission as well as at eviction.
If EVERY active slot is stalled the engine raises :class:`OutOfPages`
instead of spinning: pages are only ever freed by an eviction, an eviction
requires some slot to advance, so an all-stalled step can never make
progress again (size ``n_pages`` for the expected concurrency instead).

MoE caveat: capacity-factor routing in ``moe_forward`` drops tokens as a
function of BATCH composition, so for moe-family models the tokens served
for a prompt can depend on which other requests are co-scheduled (and an
identical prompt may decode differently under different load).  Dense,
ssm, and hybrid families are batch-composition-independent; parity tests
pin moe only at the single-step level for this reason.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import List, Optional

import jax
import numpy as np

from .paging import OutOfPages, PageAllocator

FREE, PREFILL, DECODE = "free", "prefill", "decode"


def _jitted(fn):
    """Many engines over one ModelAPI compile once: the jitted step is
    cached as an attribute of the underlying ``paged_decode_step``
    callable itself, so it lives exactly as long as the model API does
    (no global registry to leak across models)."""
    cached = getattr(fn, "_serve_jitted", None)
    if cached is None:
        cached = jax.jit(fn, donate_argnums=(1,))
        fn._serve_jitted = cached
    return cached


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int
    eos_id: Optional[int] = None
    generated: List[int] = dataclasses.field(default_factory=list)
    arrival_step: int = -1
    first_token_step: int = -1
    finish_step: int = -1

    @property
    def done(self) -> bool:
        return self.finish_step >= 0


class _Slot:
    __slots__ = ("index", "state", "req", "pos")

    def __init__(self, index: int):
        self.index = index
        self.state = FREE
        self.req: Optional[Request] = None
        self.pos = 0          # tokens fed into the cache so far


class ServeEngine:
    def __init__(self, api, params, *, n_slots: int = 4, page_size: int = 16,
                 max_len: int = 128, n_pages: Optional[int] = None,
                 admission: str = "continuous"):
        assert api.has_paged, f"{api.cfg.name}: family has no paged decode"
        assert admission in ("continuous", "static"), admission
        self.api = api
        self.params = params
        self.page_size = page_size
        self.max_pages = -(-max_len // page_size)
        self.max_len = self.max_pages * page_size
        self.n_slots = n_slots
        self.admission = admission
        # default pool: every slot can hold a full-length request (+scratch)
        self.n_pages = n_pages or 1 + n_slots * self.max_pages
        self.alloc = PageAllocator(self.n_pages)
        self.cache = api.init_paged_cache(params, n_slots, self.n_pages,
                                          page_size)
        self.page_table = np.zeros((n_slots, self.max_pages), np.int32)
        self.slots = [_Slot(i) for i in range(n_slots)]
        self.queue: deque = deque()
        self._step_fn = _jitted(api.paged_decode_step)
        self._next_rid = 0
        self.step_count = 0       # the engine clock (idle ticks included)
        self.real_steps = 0       # steps that actually ran the model
        self.generated_total = 0
        self.stall_events = 0

    # ------------------------------------------------------------- intake --
    def submit(self, prompt, max_new_tokens: int,
               eos_id: Optional[int] = None) -> Request:
        prompt = [int(t) for t in prompt]
        assert prompt, "empty prompt"
        need = len(prompt) + max_new_tokens
        assert need <= self.max_len, (
            f"request needs {need} tokens > max_len {self.max_len} "
            "(the paged cache does not wrap)")
        req = Request(rid=self._next_rid, prompt=prompt,
                      max_new_tokens=max_new_tokens, eos_id=eos_id,
                      arrival_step=self.step_count)
        self._next_rid += 1
        self.queue.append(req)
        return req

    @property
    def has_work(self) -> bool:
        return bool(self.queue) or any(s.state != FREE for s in self.slots)

    @property
    def active_slots(self) -> int:
        return sum(s.state != FREE for s in self.slots)

    # ---------------------------------------------------------- scheduling --
    def _admit(self) -> None:
        free = [s for s in self.slots if s.state == FREE]
        if self.admission == "static" and len(free) < self.n_slots:
            return                       # head-of-line: wait for the batch
        for slot in free:
            if not self.queue:
                break
            slot.req = self.queue.popleft()
            slot.pos = 0
            slot.state = PREFILL
            # defense in depth vs eviction-time reset: a recycled slot must
            # start from pristine recurrent state no matter what ran (or
            # idled) in it since the last eviction
            if self.api.reset_slot is not None:
                self.cache = self.api.reset_slot(self.cache, slot.index)

    def _ensure_page(self, slot: _Slot) -> bool:
        """Allocate the page slot.pos falls in, if not already owned.
        Returns False (stall) when the pool is dry."""
        if slot.pos % self.page_size:
            return True
        pidx = slot.pos // self.page_size
        if self.page_table[slot.index, pidx]:
            return True
        try:
            self.page_table[slot.index, pidx] = self.alloc.alloc()
            return True
        except OutOfPages:
            self.stall_events += 1
            return False

    def _evict(self, slot: _Slot) -> None:
        row = self.page_table[slot.index]
        self.alloc.free(row[row > 0])
        row[:] = 0
        if self.api.reset_slot is not None:
            self.cache = self.api.reset_slot(self.cache, slot.index)
        slot.req = None
        slot.pos = 0
        slot.state = FREE

    # -------------------------------------------------------------- stepping --
    def idle_tick(self) -> None:
        """Advance the engine clock without touching the device (used by
        open-loop drivers to fast-forward between arrivals)."""
        self.step_count += 1

    def warmup(self) -> None:
        """Compile the step function before any request is admitted (all
        writes land in the scratch page; the all-False advance mask keeps
        every slot's recurrent state bitwise untouched)."""
        S = self.n_slots
        import jax.numpy as jnp
        logits, self.cache = self._step_fn(
            self.params, self.cache, jnp.zeros((S, 1), jnp.int32),
            jnp.zeros((S,), jnp.int32), jnp.asarray(self.page_table),
            jnp.zeros((S,), bool))
        jax.block_until_ready(logits)        # lint: allow-host-sync (warmup)

    def step(self) -> int:
        """One engine step: admit, run the fused decode, sample, evict.
        Returns the number of tokens generated this step (0 on an idle
        step, which still advances the clock)."""
        self._admit()
        active = [s for s in self.slots if s.state != FREE]
        if not active:
            self.step_count += 1
            return 0

        S = self.n_slots
        tokens = np.zeros((S, 1), np.int32)
        positions = np.zeros((S,), np.int32)
        adv_mask = np.zeros((S,), bool)
        advance = []
        for slot in active:
            if not self._ensure_page(slot):
                positions[slot.index] = slot.pos   # stalled: re-fed later;
                continue                           # write -> scratch page
            req = slot.req
            if slot.pos < len(req.prompt):
                tokens[slot.index, 0] = req.prompt[slot.pos]
            else:
                tokens[slot.index, 0] = req.generated[-1]
            positions[slot.index] = slot.pos
            adv_mask[slot.index] = True
            advance.append(slot)

        if not advance:
            # every active slot is page-stalled.  Pages are only freed by
            # evictions and an eviction needs some slot to advance, so no
            # step can ever make progress again — fail fast rather than
            # burn device steps until the run() wedge assert.
            raise OutOfPages(
                f"deadlock: all {len(active)} active slot(s) stalled on an "
                f"exhausted pool of {self.n_pages - 1} page(s) and no "
                "eviction can free any; size n_pages for the expected "
                "concurrency")

        import jax.numpy as jnp
        logits, self.cache = self._step_fn(
            self.params, self.cache, jnp.asarray(tokens),
            jnp.asarray(positions), jnp.asarray(self.page_table),
            jnp.asarray(adv_mask))
        # the engine's ONE sync per step: sampling needs the logits on host
        lg = np.asarray(
            logits[:, 0, :self.api.cfg.vocab])   # lint: allow-host-sync

        made = 0
        for slot in advance:
            req = slot.req
            slot.pos += 1
            if slot.pos < len(req.prompt):
                continue                           # still prefilling
            if slot.state == PREFILL:
                slot.state = DECODE
            tok = int(np.argmax(lg[slot.index]))
            req.generated.append(tok)
            made += 1
            if req.first_token_step < 0:
                req.first_token_step = self.step_count
            if ((req.eos_id is not None and tok == req.eos_id)
                    or len(req.generated) >= req.max_new_tokens):
                req.finish_step = self.step_count
                self._evict(slot)
        self.generated_total += made
        self.step_count += 1
        self.real_steps += 1
        return made

    def run(self, max_steps: int = 100_000) -> None:
        """Drain the queue and all active slots (closed-loop drivers)."""
        while self.has_work:
            self.step()
            assert self.step_count < max_steps, "serve engine wedged"

    # --------------------------------------------------------------- weights --
    def set_params(self, params) -> None:
        """Hot-swap served weights (consensus-view snapshots): same shapes,
        so the compiled step is reused — no retrace."""
        self.params = params
