"""Decentralized serving engine (ISSUE 7): continuous batching over a paged
KV cache, with a consensus-view bridge into the live decentralized trainer."""
from .bridge import ConsensusBridge, ConsensusSnapshot, served_divergence
from .engine import Request, ServeEngine
from .paging import OutOfPages, PageAllocator

__all__ = [
    "ConsensusBridge",
    "ConsensusSnapshot",
    "OutOfPages",
    "PageAllocator",
    "Request",
    "ServeEngine",
    "served_divergence",
]
