"""Consensus-view serving bridge: serve snapshots of a LIVE flat trainer.

The paper's decentralized learners never hold one canonical model — each
learner a has its own w_a, and the closest thing to "the model" is the
consensus mean w̄ = (1/n) Σ w_a.  This bridge snapshots that mean out of a
running ``Trainer`` (flat or pytree engine — ``params_tree`` handles both)
and hot-swaps it into a :class:`~repro.serve.engine.ServeEngine` without
retracing (same shapes, ``set_params``).

Because training keeps moving while a snapshot is being served, the bridge
quantifies TWO kinds of gap:

  * **staleness** — how far training has advanced past the served snapshot
    (``steps_behind``), plus the learner spread sigma_w = sqrt(sigma_w^2)
    at snapshot time vs now.  When the paper's self-adjusting LR is doing
    its job, sigma_w stays bounded and the served mean is a faithful proxy
    for every learner.
  * **served-output divergence** — what that parameter gap does to actual
    served logits: top-1 agreement and logit deltas between the snapshot
    and the current consensus mean on a probe batch
    (:func:`served_divergence`).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from ..core.util import (learner_var, masked_learner_mean,
                         masked_learner_var)


@dataclasses.dataclass(frozen=True)
class ConsensusSnapshot:
    params: Any               # consensus mean, single-learner pytree
    step: int                 # trainer step the snapshot was taken at
    consensus_dist: float     # sigma_w = sqrt(sigma_w^2) at snapshot time
    n_active: int = 0         # live learners averaged into the mean


class ConsensusBridge:
    """Snapshot the consensus mean out of a live trainer for serving.

    Membership-aware: an elastic state (``state.members`` set) averages
    only the ACTIVE learners — a crashed learner's quarantined row is
    frozen at its time-of-death weights (or worse), and folding it into
    the served mean would silently degrade every response (DESIGN §15).
    """

    def __init__(self, trainer):
        self.trainer = trainer

    def _stacked(self, state):
        return self.trainer.params_tree(state)

    @staticmethod
    def _active(state):
        members = getattr(state, "members", None)
        return None if members is None else members.active

    def snapshot(self, state) -> ConsensusSnapshot:
        stacked = self._stacked(state)
        act = self._active(state)
        if act is None:
            mean = jax.tree_util.tree_map(
                lambda x: jnp.mean(x.astype(jnp.float32), axis=0), stacked)
            dist = float(jnp.sqrt(learner_var(stacked)))
            n_act = jax.tree_util.tree_leaves(stacked)[0].shape[0]
        else:
            mean = jax.tree_util.tree_map(
                lambda x: x.astype(jnp.float32),
                masked_learner_mean(stacked, act))
            dist = float(jnp.sqrt(masked_learner_var(stacked, act)))
            n_act = int(jnp.sum(act))
        return ConsensusSnapshot(params=mean, step=int(state.step),
                                 consensus_dist=dist, n_active=int(n_act))

    def staleness(self, state, snap: ConsensusSnapshot) -> Dict[str, float]:
        """How far the live trainer has moved past a served snapshot."""
        stacked = self._stacked(state)
        act = self._active(state)
        now = (learner_var(stacked) if act is None
               else masked_learner_var(stacked, act))
        return {
            "steps_behind": int(state.step) - snap.step,
            "consensus_dist_snapshot": snap.consensus_dist,
            "consensus_dist_now": float(jnp.sqrt(now)),
        }


def served_divergence(api, params_served, params_live, tokens) -> Dict[str, float]:
    """Logit-level gap between a served snapshot and the live consensus.

    tokens: (B, S) int32 probe prompts.  Both parameter sets run the same
    prefill forward; returns top-1 agreement over all positions plus mean /
    max absolute logit deltas (over the logical vocab).
    """
    batch = {"tokens": jnp.asarray(tokens, jnp.int32)}
    v = api.cfg.vocab
    # diagnostic path, not the decode loop: pulling both logit sets to host
    # for the numpy comparison is the point
    a = np.asarray(api.apply(params_served, batch)[..., :v],
                   np.float32)                   # lint: allow-host-sync
    b = np.asarray(api.apply(params_live, batch)[..., :v],
                   np.float32)                   # lint: allow-host-sync
    agree = float(np.mean(np.argmax(a, -1) == np.argmax(b, -1)))
    diff = np.abs(a - b)
    return {"top1_agreement": agree,
            "mean_abs_logit_diff": float(diff.mean()),
            "max_abs_logit_diff": float(diff.max())}
