"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh)
combination against 512 placeholder host devices, and extract the roofline
terms from the compiled artifact.

    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-34b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh single
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST run before any other import (jax locks device count on first init).

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs import ASSIGNED, SHAPES, get_config
from ..models.model import build_model
from ..models.transformer import n_periods as layer_scan_periods
from ..optim import sgd
from . import analytic, sharding as shd
from .mesh import make_production_mesh, n_learners
from .roofline import memory_summary, roofline_from_compiled
from .train import (jit_train_step, make_decode_step, make_dpsgd_train_step,
                    make_prefill_step, make_ssgd_train_step,
                    train_state_shardings, train_state_specs)

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")

# (arch, shape) pairs that are skipped by design — see DESIGN.md §5
SKIPS = {
    ("seamless-m4t-large-v2", "long_500k"):
        "enc-dec speech model: 500k-token decode has no meaningful analogue",
}


def _decode_buf_len(cfg, seq_len: int) -> int:
    # long-context serving always uses the sliding-window variant (rotating
    # buffer of `window`); shorter decode keeps the full context.
    if seq_len > 65536:
        return min(seq_len, cfg.window)
    return seq_len


def build_lowered(arch: str, shape: str, *, multi_pod: bool, algo: str,
                  backend: str, extra: dict | None = None):
    cfg = get_config(arch)
    if extra:
        import dataclasses
        cfg = dataclasses.replace(cfg, **extra)
    seq_len, global_batch, kind = SHAPES[shape]
    mesh = make_production_mesh(multi_pod=multi_pod)
    api = build_model(cfg)

    if kind == "train":
        opt = sgd(lr=0.1, momentum=0.9)
        state_specs = train_state_specs(api, opt, mesh, algo=algo)
        state_shd = train_state_shardings(state_specs, mesh, algo=algo)
        batch_specs = api.train_batch_spec(global_batch, seq_len)
        batch_shd = shd.batch_sharding(batch_specs, mesh, stacked=False)
        if algo == "dpsgd":
            step = make_dpsgd_train_step(api, opt, mesh,
                                         gossip_backend=backend)
        else:
            step = make_ssgd_train_step(api, opt, mesh)
        with mesh:
            lowered = jit_train_step(
                step,
                in_shardings=shd.named_shardings((state_shd, batch_shd), mesh),
                out_shardings=shd.named_shardings((state_shd, None), mesh),
            ).lower(state_specs, batch_specs)
        n_tokens = global_batch * seq_len
        model_flops = 6.0 * cfg.n_active_params() * n_tokens
        return lowered, mesh, model_flops

    if kind == "prefill":
        params_specs = jax.eval_shape(api.init, jax.random.PRNGKey(0))
        params_shd = shd.params_sharding(params_specs, mesh, stacked=False)
        batch_specs = api.train_batch_spec(global_batch, seq_len)
        batch_shd = shd.batch_sharding(batch_specs, mesh, stacked=False)
        step = make_prefill_step(api)
        with mesh:
            lowered = jax.jit(
                step,
                in_shardings=shd.named_shardings((params_shd, batch_shd), mesh),
            ).lower(params_specs, batch_specs)
        model_flops = 2.0 * cfg.n_active_params() * global_batch * seq_len
        return lowered, mesh, model_flops

    # decode
    params_specs = jax.eval_shape(api.init, jax.random.PRNGKey(0))
    params_shd = shd.params_sharding(params_specs, mesh, stacked=False)
    buf_len = _decode_buf_len(cfg, seq_len)
    if cfg.family == "audio":
        enc_len = 4096  # fixed stub audio memory
        frames_spec = jax.ShapeDtypeStruct(
            (global_batch, enc_len, cfg.d_model), jnp.bfloat16
            if cfg.param_dtype == "bfloat16" else jnp.float32)
        cache_specs = jax.eval_shape(
            lambda p, f: api.init_cache(p, f, buf_len), params_specs,
            frames_spec)
    else:
        cache_specs = jax.eval_shape(
            lambda: api.init_cache(None, global_batch, buf_len))
    cache_shd = shd.cache_sharding(cache_specs, mesh)
    tok_spec = jax.ShapeDtypeStruct((global_batch, 1), jnp.int32)
    tok_shd = shd.batch_sharding(tok_spec, mesh, stacked=False)
    pos_spec = jax.ShapeDtypeStruct((), jnp.int32)
    step = make_decode_step(api)
    with mesh:
        lowered = jax.jit(
            step,
            in_shardings=shd.named_shardings(
                (params_shd, cache_shd, tok_shd, P()), mesh),
            out_shardings=shd.named_shardings((None, cache_shd), mesh),
        ).lower(params_specs, cache_specs, tok_spec, pos_spec)
    model_flops = 2.0 * cfg.n_active_params() * global_batch
    return lowered, mesh, model_flops


def run_one(arch: str, shape: str, *, multi_pod: bool, algo: str = "dpsgd",
            backend: str = "einsum", outdir: str = RESULTS_DIR,
            tag: str = "", extra: dict | None = None) -> dict:
    mesh_name = "multipod_2x16x16" if multi_pod else "pod_16x16"
    name = f"{arch}__{shape}__{mesh_name}__{algo}__{backend}"
    if tag:
        name += f"__{tag}"
    if (arch, shape) in SKIPS:
        rec = {"name": name, "status": "skipped",
               "reason": SKIPS[(arch, shape)]}
        _write(outdir, name, rec)
        print(json.dumps(rec))
        return rec

    t0 = time.time()
    try:
        lowered, mesh, model_flops = build_lowered(
            arch, shape, multi_pod=multi_pod, algo=algo, backend=backend,
            extra=extra)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        n_chips = 512 if multi_pod else 256
        cfg = get_config(arch)
        if extra:
            import dataclasses
            cfg = dataclasses.replace(cfg, **extra)
        seq_len, global_batch, kind = SHAPES[shape]
        L = n_learners(mesh)
        trip = cfg.n_layers if cfg.family == "audio" \
            else layer_scan_periods(cfg)
        if kind == "train":
            a_flops = analytic.train_flops_per_chip(cfg, global_batch,
                                                    seq_len, n_chips)
            a_bytes = analytic.train_bytes_per_chip(
                cfg, global_batch, seq_len, n_chips, L)
        elif kind == "prefill":
            a_flops = analytic.prefill_flops_per_chip(cfg, global_batch,
                                                      seq_len, n_chips)
            a_bytes = analytic.prefill_bytes_per_chip(cfg, global_batch,
                                                      seq_len, n_chips)
        else:
            capped = seq_len > 65536
            a_flops = analytic.decode_flops_per_chip(
                cfg, global_batch, seq_len, n_chips, window_capped=capped)
            a_bytes = analytic.decode_bytes_per_chip(
                cfg, global_batch, seq_len, n_chips, window_capped=capped)
        rl = roofline_from_compiled(compiled, body_trip_count=trip,
                                    analytic_flops=a_flops,
                                    analytic_bytes=a_bytes)
        mem = memory_summary(compiled)
        summ = rl.summary()
        rec = {
            "name": name, "status": "ok", "arch": arch, "shape": shape,
            "mesh": mesh_name, "algo": algo, "backend": backend,
            "n_chips": n_chips,
            "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
            "roofline": summ,
            "memory": mem,
            "model_flops_total": model_flops,
            "model_flops_per_chip": model_flops / n_chips,
            "useful_flops_ratio": (model_flops / n_chips) / max(summ["flops"],
                                                                1.0),
            "collectives_top": sorted(rl.collectives,
                                      key=lambda c: -c["link_bytes"])[:10],
        }
    except Exception as e:  # noqa: BLE001 — a dry-run failure IS the signal
        rec = {"name": name, "status": "error", "arch": arch, "shape": shape,
               "mesh": mesh_name, "algo": algo, "backend": backend,
               "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-4000:]}
    _write(outdir, name, rec)
    print(json.dumps({k: v for k, v in rec.items()
                      if k not in ("collectives_top", "traceback")}, indent=1))
    return rec


def _write(outdir, name, rec):
    os.makedirs(outdir, exist_ok=True)
    with open(os.path.join(outdir, name + ".json"), "w") as f:
        json.dump(rec, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--algo", default="dpsgd", choices=["dpsgd", "ssgd"])
    ap.add_argument("--backend", default="einsum",
                    choices=["einsum", "ppermute"])
    ap.add_argument("--tag", default="")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--outdir", default=RESULTS_DIR)
    args = ap.parse_args()

    combos = []
    if args.all:
        for arch in ASSIGNED:
            for shape in SHAPES:
                combos.append((arch, shape))
    else:
        assert args.arch and args.shape
        combos = [(args.arch, args.shape)]

    for arch, shape in combos:
        run_one(arch, shape, multi_pod=(args.mesh == "multi"),
                algo=args.algo, backend=args.backend, outdir=args.outdir,
                tag=args.tag)


if __name__ == "__main__":
    main()
