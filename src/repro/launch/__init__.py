from .mesh import (learner_axes, make_production_mesh, make_test_mesh,
                   n_learners)

__all__ = ["make_production_mesh", "make_test_mesh", "learner_axes",
           "n_learners"]
