"""Production meshes.

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod slice).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips.

The DPSGD *learner* axis is ('data',) single-pod / ('pod', 'data') multi-pod:
each learner is one model-parallel group of 16 chips — exactly the paper's
App. F "super-learner" recommendation (16 learners single-pod, 32 multi-pod).

Functions, not module constants: importing this module must never touch jax
device state (XLA_FLAGS must be set before first jax init in dryrun).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def learner_axes(mesh) -> tuple:
    """Mesh axes that enumerate DPSGD learners."""
    return tuple(a for a in mesh.axis_names if a != "model")


def n_learners(mesh) -> int:
    n = 1
    for a in learner_axes(mesh):
        n *= mesh.shape[a]
    return n


def make_test_mesh(n_data: int = 4, n_model: int = 2):
    """Small mesh for CPU tests (requires forced host device count)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))
