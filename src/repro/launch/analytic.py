"""Analytic FLOPs / HBM-bytes models for the roofline.

XLA's HloCostAnalysis counts a while-loop body ONCE (verified empirically in
this repo — see EXPERIMENTS.md §Dry-run), so for scan-over-layers models the
reported flops/bytes undercount by the trip count.  The roofline therefore
uses closed-form accounting derived from the config + input shape (this is
also how MFU is conventionally reported), and keeps the HLO numbers as a
structural cross-check.

All results are PER CHIP: totals divided by the chip count.
"""
from __future__ import annotations


from ..configs.base import ModelConfig
from ..models.transformer import period_spec

WB = {"float32": 4, "bfloat16": 2, "float16": 2}


def _attn_layers(cfg: ModelConfig) -> dict:
    """Counts of each mixer kind across the full stack."""
    spec = period_spec(cfg)
    reps = cfg.n_layers // len(spec)
    counts = {"attn": 0, "attn_local": 0, "mamba": 0, "mlstm": 0, "slstm": 0}
    moe_layers = 0
    dense_layers = 0
    for mixer, mlp in spec:
        counts[mixer] += reps
        if mlp == "moe":
            moe_layers += reps
        elif mlp == "dense":
            dense_layers += reps
    return {**counts, "moe": moe_layers, "dense_mlp": dense_layers}


def attention_flops(cfg: ModelConfig, batch: int, seq: int, *,
                    backward: bool, window_override: int | None = None) -> float:
    """Score+PV flops for the whole stack (excluded from the 6ND weight term)."""
    c = _attn_layers(cfg)
    hd = cfg.head_dim_
    H = cfg.n_heads
    total = 0.0
    for kind, n in (("attn", c["attn"]), ("attn_local", c["attn_local"])):
        if not n:
            continue
        win = cfg.window if kind == "attn_local" else 0
        if window_override is not None:
            win = window_override
        s_eff = min(seq, win) if win else seq
        # causal: each query sees ~min(pos, s_eff) keys; average ~ s_eff/2
        # when win < seq else seq/2
        avg_ctx = s_eff if (win and win < seq) else seq / 2.0
        fwd = 4.0 * batch * seq * avg_ctx * H * hd  # scores + pv, 2 matmuls
        total += n * (fwd * (3.0 if backward else 1.0))
    # mLSTM intra-chunk quadratic
    if c["mlstm"]:
        ch = cfg.scan_chunk
        di = 2 * cfg.d_model
        fwd = 4.0 * batch * seq * ch * di
        total += c["mlstm"] * fwd * (3.0 if backward else 1.0)
    return total


def train_flops_per_chip(cfg: ModelConfig, global_batch: int, seq: int,
                         n_chips: int) -> float:
    tokens = global_batch * seq
    weight_term = 6.0 * cfg.n_active_params() * tokens
    attn_term = attention_flops(cfg, global_batch, seq, backward=True)
    return (weight_term + attn_term) / n_chips


def prefill_flops_per_chip(cfg: ModelConfig, global_batch: int, seq: int,
                           n_chips: int) -> float:
    tokens = global_batch * seq
    weight_term = 2.0 * cfg.n_active_params() * tokens
    attn_term = attention_flops(cfg, global_batch, seq, backward=False)
    return (weight_term + attn_term) / n_chips


def decode_flops_per_chip(cfg: ModelConfig, global_batch: int, ctx: int,
                          n_chips: int, *, window_capped: bool) -> float:
    weight_term = 2.0 * cfg.n_active_params() * global_batch
    c = _attn_layers(cfg)
    hd, H = cfg.head_dim_, cfg.n_heads
    attn = 0.0
    for kind, n in (("attn", c["attn"]), ("attn_local", c["attn_local"])):
        win = cfg.window if (kind == "attn_local" or window_capped) else 0
        s_eff = min(ctx, win) if win else ctx
        attn += n * 4.0 * global_batch * s_eff * H * hd
    return (weight_term + attn) / n_chips


# ---------------------------------------------------------------------------
# HBM bytes
# ---------------------------------------------------------------------------

ACT_RW_TRAIN = 14.0   # fwd writes + bwd reads per activation element (std est.)
ACT_RW_FWD = 4.0


def _act_bytes(cfg: ModelConfig, batch: int, seq: int, n_chips: int,
               factor: float) -> float:
    wb = WB[cfg.compute_dtype]
    n_layers = cfg.n_layers + cfg.enc_layers
    return batch * seq * cfg.d_model * n_layers * wb * factor / n_chips


def train_bytes_per_chip(cfg: ModelConfig, global_batch: int, seq: int,
                         n_chips: int, n_learners: int,
                         gossip_neighbors: int = 1) -> float:
    wb = WB[cfg.param_dtype]
    P = cfg.n_params()
    # each learner replica is sharded over (n_chips / n_learners) chips
    p_local = P * n_learners / n_chips
    # fwd read + bwd read + grad write(f32) + momentum r/w(f32) + write
    # + gossip read of k neighbor replicas + mixed write
    weight_traffic = p_local * (3 * wb + 12 + (gossip_neighbors + 1) * wb)
    act = _act_bytes(cfg, global_batch, seq, n_chips, ACT_RW_TRAIN)
    return weight_traffic + act


def prefill_bytes_per_chip(cfg: ModelConfig, global_batch: int, seq: int,
                           n_chips: int) -> float:
    wb = WB[cfg.param_dtype]
    return cfg.n_params() * wb / n_chips \
        + _act_bytes(cfg, global_batch, seq, n_chips, ACT_RW_FWD) \
        + kv_cache_bytes(cfg, global_batch, seq, n_chips)


def kv_cache_bytes(cfg: ModelConfig, batch: int, buf: int,
                   n_chips: int) -> float:
    wb = WB[cfg.param_dtype]
    c = _attn_layers(cfg)
    per_layer = 2.0 * batch * buf * cfg.n_kv_heads * cfg.head_dim_ * wb
    n_attn = c["attn"] + c["attn_local"]
    ssm_state = (c["mamba"] * 2 * cfg.ssm_expand * cfg.d_model
                 * cfg.ssm_state * 4.0 * batch)
    return (n_attn * per_layer + ssm_state) / n_chips


def decode_bytes_per_chip(cfg: ModelConfig, global_batch: int, ctx: int,
                          n_chips: int, *, window_capped: bool) -> float:
    wb = WB[cfg.param_dtype]
    c = _attn_layers(cfg)
    weights = cfg.n_params() * wb / n_chips      # every weight read once
    buf_full = min(ctx, cfg.window) if window_capped else ctx
    cache_read = 0.0
    for kind, n in (("attn", c["attn"]), ("attn_local", c["attn_local"])):
        buf = min(ctx, cfg.window) if kind == "attn_local" else buf_full
        cache_read += n * 2.0 * global_batch * buf * cfg.n_kv_heads \
            * cfg.head_dim_ * wb
    ssm = (c["mamba"] + c["mlstm"] + c["slstm"]) * 2 * cfg.ssm_expand \
        * cfg.d_model * cfg.ssm_state * 4.0 * global_batch * 2
    return weights + (cache_read + ssm) / n_chips


# ---------------------------------------------------------------------------
# gossip (cross-learner) bytes — the DPSGD-specific collective term
# ---------------------------------------------------------------------------

def gossip_link_bytes_per_chip(cfg: ModelConfig, n_chips: int,
                               n_learners: int, backend: str) -> float:
    """Per-chip ICI bytes of one gossip round.
    einsum backend: the L x L mixing matmul all-gathers every replica shard
    (L x p_local per chip); ppermute ring: 2 neighbor exchanges of p_local."""
    wb = WB[cfg.param_dtype]
    p_local = cfg.n_params() * wb * n_learners / n_chips
    if backend == "einsum":
        return n_learners * p_local
    return 2.0 * p_local
