"""Production (pjit/shard_map) step builders.

Training (the paper's setting):
  * DPSGD  — params carry a leading learner axis sharded over the learner
    mesh axes; gradients are purely local (NO gradient collective — the
    paper's point); the only cross-learner traffic is the gossip mix.
       gossip_backend='einsum'   : paper-faithful reference (L x L mixing
                                   matrix; XLA emits an all-gather over the
                                   learner axis — O(L*P) traffic, DESIGN §2)
       gossip_backend='ppermute' : TPU-native ring gossip via shard_map +
                                   collective-permute — O(P) traffic
                                   (beyond-paper optimization, DESIGN §2)
  * AD-PSGD — straggler-tolerant pairwise gossip against a stale published
    weight buffer (staleness-bounded, explicit per-learner age/clock so the
    step is one jitted SPMD program); reuses mix_ppermute_pair — ONE
    collective-permute per step (DESIGN §3).
  * SSGD   — classic data parallelism: replicated params, psum'd grads
    (the baseline the paper compares against).

Serving: prefill (full forward) and decode (one token vs a rotating KV
cache) with the inference sharding rules from launch/sharding.py.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

try:                                      # jax >= 0.6
    _shard_map = jax.shard_map
except AttributeError:                    # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

from ..core.dpsgd import (member_active_mask, mix_einsum, mix_ppermute_pair,
                          mix_ppermute_pair_flat, mix_ppermute_ring,
                          mix_ppermute_ring_flat, mix_ppermute_schedule,
                          mix_ppermute_schedule_flat, straggler_active_mask)
from ..core.schedule import make_schedule
from ..models.model import ModelAPI
from ..models.shard_hints import activation_batch_axes
from ..optim import Optimizer, apply_updates
from . import sharding as shd
from .mesh import learner_axes, n_learners


def jit_train_step(step_fn: Callable, **jit_kwargs) -> Callable:
    """jit a ``(state, batch) -> (state, metrics)`` step with state donation.

    All production step builders below are pure; donating the state argument
    lets XLA update the parameter / momentum / published-buffer arrays in
    place (no double-buffering of model-sized state).  A consumed state must
    not be reused — rebind it: ``state, m = step(state, batch)``.  Probe
    entry points (make_probe_step) deliberately do NOT donate: the state
    outlives a measurement pass.
    """
    return jax.jit(step_fn, donate_argnums=(0,), **jit_kwargs)


class PjitTrainState(NamedTuple):
    params: Any
    opt_state: Any
    step: jnp.ndarray
    rng: jax.Array
    # -- adpsgd only (None otherwise) --------------------------------------
    buffer: Any = None    # last-published weights, stacked like params
    age: Any = None       # (L,) int32 ticks since each learner published
    # -- elastic membership operands (None for a static fleet; DESIGN §15) --
    active: Any = None       # (L,) bool — live fleet members
    slow_every: Any = None   # (L,) int32 — completes a step every k ticks
    drop_round: Any = None   # () bool — this tick's gossip round is dropped


def membership_operands(membership, drop_round: bool = False) -> dict:
    """The launch-layer half of ``MultiLearnerTrainer.set_membership``:
    device operands for a host-side ``core.membership.Membership``, to be
    swapped in between steps with ``state._replace(**...)`` — same shapes,
    so the compiled step is never invalidated."""
    return dict(active=jnp.asarray(membership.active),
                slow_every=jnp.asarray(membership.slow_every, jnp.int32),
                drop_round=jnp.asarray(bool(drop_round)))


# ---------------------------------------------------------------------------
# DPSGD
# ---------------------------------------------------------------------------

def make_dpsgd_train_step(api: ModelAPI, optimizer: Optimizer, mesh,
                          topology: str = "random_pair",
                          gossip_backend: str = "einsum",
                          gossip_fuse: str = "flat",
                          gossip_rounds: int = 1) -> Callable:
    """``topology`` is compiled through core.schedule.make_schedule, so the
    SPMD path runs the same GossipSchedule tables as the research trainer
    (DESIGN §12).  ``gossip_backend='ppermute'``: deterministic schedules
    (ring/torus/full/hierarchical/exp/one_peer_exp) derive their
    collective-permute sequence straight from the schedule — K permutes per
    round, parity-pinned against the einsum step matrix; random matchings
    cannot be a compiled collective schedule, so they substitute the ring
    (the pre-schedule behavior — use the einsum backend for true random
    pairing under pjit).  ``gossip_fuse``: 'flat' permutes each device's
    LOCAL parameter shard as one lane-aligned (T_local, 128) buffer —
    collectives per step independent of leaf count (DESIGN §11); 'leaf' is
    the per-leaf reference collective schedule."""
    L = n_learners(mesh)
    l_axes = learner_axes(mesh)
    assert gossip_fuse in ("flat", "leaf"), gossip_fuse
    sched = make_schedule(topology, L, rounds=gossip_rounds)
    if (getattr(optimizer, "wants_mixed", False)
            and getattr(optimizer, "static_mixing_only", False)
            and sched is not None and sched.time_varying):
        raise ValueError(
            "optimizer assumes a static mixing matrix but "
            f"topology='{topology}' compiles to a time-varying "
            "GossipSchedule (see optim/decentlam.py)")

    def gossip(params, key, step):
        if sched is None:                      # solo: no mixing
            return params
        if gossip_backend == "einsum":
            return mix_einsum(params, sched.step_matrix(key, step))
        # schedule-driven gossip inside shard_map (only the learner axes
        # are mapped)
        specs = shd.params_sharding(params, mesh, stacked=True)

        def local(p):
            if sched.randomized:               # ring stand-in (docstring)
                return (mix_ppermute_ring_flat(p, l_axes)
                        if gossip_fuse == "flat"
                        else mix_ppermute_ring(p, l_axes))
            if gossip_fuse == "flat":
                return mix_ppermute_schedule_flat(p, l_axes, step, sched)
            return mix_ppermute_schedule(p, l_axes, step, sched)

        # the flat view concatenates leaves with different model-axis
        # replication into one buffer, which defeats shard_map's static
        # replication inference — the mix itself never touches the model
        # axes (every model shard runs the identical elementwise program),
        # so the check is soundly skipped (DESIGN §11)
        return _shard_map(local, mesh=mesh, in_specs=(specs,),
                             out_specs=specs,
                             check_rep=gossip_fuse != "flat")(params)

    def train_step(state: PjitTrainState, batch):
        # batch leaves: (GB, ...) -> (L, B_local, ...)
        stacked_batch = jax.tree_util.tree_map(
            lambda x: x.reshape((L, x.shape[0] // L) + x.shape[1:]), batch)
        # spmd_axis_name: in-model activation constraints (residual_hint)
        # see the learner dim sharded over the learner mesh axes; the
        # per-learner batch itself is unsharded -> batch axes context ()
        with activation_batch_axes(()):
            losses, grads = jax.vmap(jax.value_and_grad(api.loss_fn),
                                     in_axes=(0, 0),
                                     spmd_axis_name=l_axes)(
                state.params, stacked_batch)
        key = jax.random.fold_in(state.rng, state.step)
        mixed = gossip(state.params, key, state.step)  # paper Eq. 2 ordering
        if getattr(optimizer, "wants_mixed", False):   # decentlam correction
            updates, opt_state = jax.vmap(optimizer.update)(
                grads, state.opt_state, state.params, mixed)
        else:
            updates, opt_state = jax.vmap(optimizer.update)(
                grads, state.opt_state, state.params)
        new_params = apply_updates(mixed, updates)
        metrics = {"loss": jnp.mean(losses)}
        return PjitTrainState(new_params, opt_state, state.step + 1,
                              state.rng), metrics

    return train_step


# ---------------------------------------------------------------------------
# AD-PSGD: straggler-tolerant pairwise gossip against a stale buffer
# ---------------------------------------------------------------------------

def make_adpsgd_train_step(api: ModelAPI, optimizer: Optimizer, mesh, *,
                           max_staleness: int = 4, slow_learner: int = -1,
                           slow_factor: int = 1,
                           gossip_fuse: str = "flat",
                           elastic: bool = False) -> Callable:
    """One asynchronous-gossip tick as an SPMD program (DESIGN §3).

    Same simulation contract as the vmap research path: each learner mixes
    its live weights with ONE partner's last-*published* weights (hypercube
    ppermute schedule, one collective-permute), the partner's buffer may lag
    by up to ``max_staleness`` ticks, and an injected straggler only
    completes (and publishes) every ``slow_factor`` ticks.  With
    ``max_staleness=0`` and no straggler this is synchronous pairwise DPSGD.

    ``elastic=True`` (DESIGN §15): the state carries membership OPERANDS
    (``active``/``slow_every``/``drop_round`` — see
    :func:`membership_operands`); liveness and per-learner tick divisors
    replace the single static straggler, a hypercube pair mixes only when
    both endpoints are live (the gate ppermutes alongside the buffer), a
    dead learner's rows stay quarantined bitwise, and the loss averages
    the active learners only.  A membership change is a same-shape operand
    swap — no retrace.
    """
    L = n_learners(mesh)
    l_axes = learner_axes(mesh)
    assert gossip_fuse in ("flat", "leaf"), gossip_fuse
    if (getattr(optimizer, "wants_mixed", False)
            and getattr(optimizer, "static_mixing_only", False)):
        raise ValueError("optimizer assumes a static mixing matrix but "
                         "AD-PSGD gossips over a time-varying pairwise "
                         "schedule (see optim/decentlam.py)")
    if elastic and getattr(optimizer, "wants_mixed", False):
        raise ValueError("a mixing-matrix-corrected optimizer (decentlam) "
                         "assumes a static fleet (see core/trainer.py)")

    def gossip(params, buffer, age, step, act, drop):
        specs = shd.params_sharding(params, mesh, stacked=True)
        age_spec = P(tuple(l_axes))

        def local(p, buf, a, *rest):
            fresh = a[0] >= max_staleness          # forced publish (bound)
            remote = jax.tree_util.tree_map(
                lambda w, b: jnp.where(fresh, w, b), p, buf)
            gate = None
            if rest:    # elastic: liveness x not-dropped gates the mix
                gate = (rest[0][0].astype(jnp.float32)
                        * (1.0 - rest[1].astype(jnp.float32)))
            if gossip_fuse == "flat":
                return mix_ppermute_pair_flat(p, l_axes, step, remote=remote,
                                              gate=gate)
            return mix_ppermute_pair(p, l_axes, step, remote=remote,
                                     gate=gate)

        in_specs = (specs, specs, age_spec)
        args = (params, buffer, age)
        if act is not None:
            in_specs += (age_spec, P())
            args += (act, drop)
        # check_rep: see make_dpsgd_train_step — the flat view breaks static
        # replication inference, not actual replication
        return _shard_map(local, mesh=mesh,
                             in_specs=in_specs,
                             out_specs=specs,
                             check_rep=gossip_fuse != "flat")(*args)

    def train_step(state: PjitTrainState, batch):
        stacked_batch = jax.tree_util.tree_map(
            lambda x: x.reshape((L, x.shape[0] // L) + x.shape[1:]), batch)
        with activation_batch_axes(()):
            losses, grads = jax.vmap(jax.value_and_grad(api.loss_fn),
                                     in_axes=(0, 0),
                                     spmd_axis_name=l_axes)(
                state.params, stacked_batch)
        if elastic:
            live = state.active
            active = member_active_mask(state.step, live, state.slow_every)
            fresh = (state.age >= max_staleness) & live
            mixed = gossip(state.params, state.buffer, state.age, state.step,
                           live, state.drop_round)
        else:
            active = straggler_active_mask(state.step, L, slow_learner,
                                           slow_factor)
            fresh = state.age >= max_staleness
            mixed = gossip(state.params, state.buffer, state.age, state.step,
                           None, None)
        if getattr(optimizer, "wants_mixed", False):   # decentlam correction
            updates, opt_state_new = jax.vmap(optimizer.update)(
                grads, state.opt_state, state.params, mixed)
        else:
            updates, opt_state_new = jax.vmap(optimizer.update)(
                grads, state.opt_state, state.params)
        stepped = apply_updates(mixed, updates)

        def sel(mask):
            return lambda a, b: jnp.where(
                mask.reshape((-1,) + (1,) * (a.ndim - 1)), a, b)
        new_params = jax.tree_util.tree_map(sel(active), stepped, state.params)
        opt_state = jax.tree_util.tree_map(sel(active), opt_state_new,
                                           state.opt_state)
        # active learners publish their new weights; forced-fresh inactive
        # ones re-publish their (unchanged) in-progress weights — both read
        # off new_params
        buffer = jax.tree_util.tree_map(sel(active | fresh), new_params,
                                        state.buffer)
        age = jnp.where(active | fresh, 0, state.age + 1)
        if elastic:
            nact = jnp.maximum(jnp.sum(state.active), 1).astype(jnp.float32)
            loss = jnp.sum(jnp.where(state.active, losses, 0.0)) / nact
            metrics = {"loss": loss, "n_active": nact,
                       "staleness_max": jnp.max(jnp.where(
                           fresh | ~state.active, 0, state.age))}
        else:
            metrics = {"loss": jnp.mean(losses),
                       "staleness_max": jnp.max(jnp.where(fresh, 0,
                                                          state.age))}
        return PjitTrainState(new_params, opt_state, state.step + 1,
                              state.rng, buffer=buffer, age=age,
                              active=state.active,
                              slow_every=state.slow_every,
                              drop_round=state.drop_round), metrics

    return train_step


# ---------------------------------------------------------------------------
# SSGD baseline
# ---------------------------------------------------------------------------

def make_ssgd_train_step(api: ModelAPI, optimizer: Optimizer, mesh) -> Callable:
    def train_step(state: PjitTrainState, batch):
        loss, grads = jax.value_and_grad(api.loss_fn)(state.params, batch)
        updates, opt_state = optimizer.update(grads, state.opt_state,
                                              state.params)
        new_params = apply_updates(state.params, updates)
        return PjitTrainState(new_params, opt_state, state.step + 1,
                              state.rng), {"loss": loss}

    return train_step


# ---------------------------------------------------------------------------
# landscape probe (sharded entry point, DESIGN §10)
# ---------------------------------------------------------------------------

def make_probe_step(api: ModelAPI, mesh, *, alpha: float, stacked: bool,
                    lanczos_iters: int = 8,
                    hutchinson_samples: int = 4) -> Callable:
    """(params, batch, key) -> landscape.ProbeResult under the mesh.

    The HVPs are plain jvp-of-grad through ``api.loss_fn``, so under jit
    they inherit exactly the step's parameter/activation shardings — no
    extra sharding rules.  ``stacked`` mirrors the train-step layout:
    True for DPSGD/AD-PSGD ((L, ...) params — covariance terms measured
    across learners), False for the SSGD path (single replica — the
    spread terms are 0 and the probe feeds the AutoLR controller with
    sharpness + gradient noise scale only).

    One SPMD caveat: the Lanczos basis lives on the flat (T, 128) view,
    which XLA must regather from model-sharded params; the reorth loop
    therefore runs through the jnp oracle (``reorth='ref'``) so the probe
    stays a legal single program on any mesh.  At probe cadence (every
    10-100 steps) the regather is noise; the fused Pallas path is for the
    research trainer and single-device probes.
    """
    L = n_learners(mesh)

    def probe(params, batch, key):
        stacked_batch = jax.tree_util.tree_map(
            lambda x: x.reshape((L, x.shape[0] // L) + x.shape[1:]), batch)
        from ..landscape import probe_landscape
        return probe_landscape(api.loss_fn, params, stacked_batch, key,
                               alpha=alpha, lanczos_iters=lanczos_iters,
                               hutchinson_samples=hutchinson_samples,
                               stacked=stacked, reorth="ref")

    return probe


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def make_prefill_step(api: ModelAPI) -> Callable:
    def prefill(params, batch):
        return api.apply(params, batch)
    return prefill


def make_decode_step(api: ModelAPI) -> Callable:
    def decode(params, cache, tokens, pos):
        return api.decode_step(params, cache, tokens, pos)
    return decode


# ---------------------------------------------------------------------------
# spec builders (shapes only — nothing allocated; dryrun + tests share these)
# ---------------------------------------------------------------------------

def stacked_param_specs(api: ModelAPI, L: int):
    single = jax.eval_shape(api.init, jax.random.PRNGKey(0))
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct((L,) + s.shape, s.dtype), single)


def train_state_specs(api: ModelAPI, optimizer: Optimizer, mesh, *,
                      algo: str, elastic: bool = False):
    L = n_learners(mesh)
    buffer = age = None
    active = slow_every = drop_round = None
    if algo in ("dpsgd", "adpsgd"):
        p = stacked_param_specs(api, L)
        o = jax.eval_shape(lambda q: jax.vmap(optimizer.init)(q), p)
        if algo == "adpsgd":
            buffer = p
            age = jax.ShapeDtypeStruct((L,), jnp.int32)
        if elastic:
            active = jax.ShapeDtypeStruct((L,), jnp.bool_)
            slow_every = jax.ShapeDtypeStruct((L,), jnp.int32)
            drop_round = jax.ShapeDtypeStruct((), jnp.bool_)
    else:
        p = jax.eval_shape(api.init, jax.random.PRNGKey(0))
        o = jax.eval_shape(optimizer.init, p)
    return PjitTrainState(
        params=p, opt_state=o,
        step=jax.ShapeDtypeStruct((), jnp.int32),
        rng=jax.ShapeDtypeStruct((2,), jnp.uint32),
        buffer=buffer, age=age, active=active, slow_every=slow_every,
        drop_round=drop_round)


def train_state_shardings(state_specs: PjitTrainState, mesh, *, algo: str):
    stacked = algo in ("dpsgd", "adpsgd")
    p = shd.params_sharding(state_specs.params, mesh, stacked=stacked)
    # optimizer state mirrors params (momentum etc.), scalars replicated
    def opt_spec(path, leaf):
        if leaf.ndim <= 1:
            return P(*([None] * leaf.ndim))
        return shd.leaf_spec(path, leaf, mesh.shape["model"],
                             learner_axes=(tuple(
                                 a for a in mesh.axis_names if a != "model")
                                 if stacked else None))
    flat, treedef = jax.tree_util.tree_flatten_with_path(state_specs.opt_state)
    o = jax.tree_util.tree_unflatten(
        treedef, [opt_spec(pa, l) for pa, l in flat])
    buffer = age = None
    active = slow_every = drop_round = None
    if algo == "adpsgd":
        buffer = p
        age = P(learner_axes(mesh))
    if state_specs.active is not None:   # elastic membership operands
        active = P(learner_axes(mesh))
        slow_every = P(learner_axes(mesh))
        drop_round = P()
    return PjitTrainState(params=p, opt_state=o, step=P(), rng=P(),
                          buffer=buffer, age=age, active=active,
                          slow_every=slow_every, drop_round=drop_round)
