"""Production (pjit/shard_map) step builders.

Training (the paper's setting):
  * DPSGD  — params carry a leading learner axis sharded over the learner
    mesh axes; gradients are purely local (NO gradient collective — the
    paper's point); the only cross-learner traffic is the gossip mix.
       gossip_backend='einsum'   : paper-faithful reference (L x L mixing
                                   matrix; XLA emits an all-gather over the
                                   learner axis — O(L*P) traffic)
       gossip_backend='ppermute' : TPU-native ring gossip via shard_map +
                                   collective-permute — O(P) traffic
                                   (beyond-paper optimization, see §Perf)
  * SSGD   — classic data parallелism: replicated params, psum'd grads
    (the baseline the paper compares against).

Serving: prefill (full forward) and decode (one token vs a rotating KV
cache) with the inference sharding rules from launch/sharding.py.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..core.dpsgd import mix_einsum, mix_ppermute_ring
from ..core.topology import random_pair_matrix, ring_matrix
from ..models.model import ModelAPI
from ..models.shard_hints import activation_batch_axes
from ..optim import Optimizer, apply_updates
from . import sharding as shd
from .mesh import learner_axes, n_learners


class PjitTrainState(NamedTuple):
    params: Any
    opt_state: Any
    step: jnp.ndarray
    rng: jax.Array


# ---------------------------------------------------------------------------
# DPSGD
# ---------------------------------------------------------------------------

def make_dpsgd_train_step(api: ModelAPI, optimizer: Optimizer, mesh,
                          topology: str = "random_pair",
                          gossip_backend: str = "einsum") -> Callable:
    L = n_learners(mesh)
    l_axes = learner_axes(mesh)

    def gossip(params, key):
        if gossip_backend == "einsum":
            if topology == "ring":
                m = ring_matrix(L)
            else:
                m = random_pair_matrix(key, L)
            return mix_einsum(params, m)
        # ppermute ring inside shard_map (only the learner axes are mapped)
        specs = shd.params_sharding(params, mesh, stacked=True)

        def local(p):
            mixed = mix_ppermute_ring(p, l_axes)
            return mixed

        return jax.shard_map(local, mesh=mesh, in_specs=(specs,),
                             out_specs=specs)(params)

    def train_step(state: PjitTrainState, batch):
        # batch leaves: (GB, ...) -> (L, B_local, ...)
        stacked_batch = jax.tree_util.tree_map(
            lambda x: x.reshape((L, x.shape[0] // L) + x.shape[1:]), batch)
        # spmd_axis_name: in-model activation constraints (residual_hint)
        # see the learner dim sharded over the learner mesh axes; the
        # per-learner batch itself is unsharded -> batch axes context ()
        with activation_batch_axes(()):
            losses, grads = jax.vmap(jax.value_and_grad(api.loss_fn),
                                     in_axes=(0, 0),
                                     spmd_axis_name=l_axes)(
                state.params, stacked_batch)
        updates, opt_state = jax.vmap(optimizer.update)(
            grads, state.opt_state, state.params)
        key = jax.random.fold_in(state.rng, state.step)
        mixed = gossip(state.params, key)              # paper Eq. 2 ordering
        new_params = apply_updates(mixed, updates)
        metrics = {"loss": jnp.mean(losses)}
        return PjitTrainState(new_params, opt_state, state.step + 1,
                              state.rng), metrics

    return train_step


# ---------------------------------------------------------------------------
# SSGD baseline
# ---------------------------------------------------------------------------

def make_ssgd_train_step(api: ModelAPI, optimizer: Optimizer, mesh) -> Callable:
    def train_step(state: PjitTrainState, batch):
        loss, grads = jax.value_and_grad(api.loss_fn)(state.params, batch)
        updates, opt_state = optimizer.update(grads, state.opt_state,
                                              state.params)
        new_params = apply_updates(state.params, updates)
        return PjitTrainState(new_params, opt_state, state.step + 1,
                              state.rng), {"loss": loss}

    return train_step


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def make_prefill_step(api: ModelAPI) -> Callable:
    def prefill(params, batch):
        return api.apply(params, batch)
    return prefill


def make_decode_step(api: ModelAPI) -> Callable:
    def decode(params, cache, tokens, pos):
        return api.decode_step(params, cache, tokens, pos)
    return decode


# ---------------------------------------------------------------------------
# spec builders (shapes only — nothing allocated; dryrun + tests share these)
# ---------------------------------------------------------------------------

def stacked_param_specs(api: ModelAPI, L: int):
    single = jax.eval_shape(api.init, jax.random.PRNGKey(0))
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct((L,) + s.shape, s.dtype), single)


def train_state_specs(api: ModelAPI, optimizer: Optimizer, mesh, *,
                      algo: str):
    L = n_learners(mesh)
    if algo == "dpsgd":
        p = stacked_param_specs(api, L)
        o = jax.eval_shape(lambda q: jax.vmap(optimizer.init)(q), p)
    else:
        p = jax.eval_shape(api.init, jax.random.PRNGKey(0))
        o = jax.eval_shape(optimizer.init, p)
    return PjitTrainState(
        params=p, opt_state=o,
        step=jax.ShapeDtypeStruct((), jnp.int32),
        rng=jax.ShapeDtypeStruct((2,), jnp.uint32))


def train_state_shardings(state_specs: PjitTrainState, mesh, *, algo: str):
    stacked = algo == "dpsgd"
    p = shd.params_sharding(state_specs.params, mesh, stacked=stacked)
    # optimizer state mirrors params (momentum etc.), scalars replicated
    def opt_spec(path, leaf):
        if leaf.ndim <= 1:
            return P(*([None] * leaf.ndim))
        return shd.leaf_spec(path, leaf, mesh.shape["model"],
                             learner_axes=(tuple(
                                 a for a in mesh.axis_names if a != "model")
                                 if stacked else None))
    flat, treedef = jax.tree_util.tree_flatten_with_path(state_specs.opt_state)
    o = jax.tree_util.tree_unflatten(
        treedef, [opt_spec(pa, l) for pa, l in flat])
    return PjitTrainState(params=p, opt_state=o, step=P(), rng=P())
