"""Sharding rules: pytree path -> PartitionSpec.

Megatron-style tensor parallelism over the 'model' axis, expressed as a
name-aware heuristic that is exact for every architecture in the registry:

  * 1-D leaves (norms, biases, gates)            -> replicated
  * 'wo' / 'w2' / 'down' / 'out_proj' leaves     -> row-parallel (first
    divisible dim), closing the Megatron col->row pair so the only FFN/attn
    collective is the one all-reduce after the row matmul
  * expert tensors (path contains 'mlp' and ndim==3, or 'router')
        -> expert-parallel over dim 0 when E % model == 0, else shard d_ff
  * everything else                              -> column-parallel (largest
    divisible dim, ties broken toward the last dim)

Training adds a leading learner dim sharded over the learner axes.
"""
from __future__ import annotations


import jax
from jax.sharding import PartitionSpec as P

ROW_TOKENS = ("wo", "w2", "down", "out_proj")


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
                    for p in path).lower()


def _pick_dim(shape, model_size: int, prefer_first: bool):
    divisible = [i for i, s in enumerate(shape) if s % model_size == 0 and
                 s >= model_size]
    if not divisible:
        return None
    best = max(divisible, key=lambda i: (shape[i], -i if prefer_first else i))
    return best


def leaf_spec(path, leaf, model_size: int, *, model_axis: str = "model",
              learner_axes=None) -> P:
    """PartitionSpec for one (possibly learner-stacked) param leaf."""
    name = _path_str(path)
    shape = leaf.shape
    lead = ()
    if learner_axes:
        lead = (learner_axes,)
        shape = shape[1:]

    if len(shape) <= 1:
        return P(*lead, *([None] * len(shape)))

    is_expert = ("mlp" in name and len(shape) == 3) or \
                ("experts" in name and len(shape) == 3)
    row = any(t in name for t in ROW_TOKENS)

    if is_expert:
        E = shape[0]
        if E % model_size == 0:
            dim = 0
        else:
            # shard the ff dim: w1/w3 (E, d, ff) -> 2 ; w2 (E, ff, d) -> 1
            dim = 1 if row else 2
            if shape[dim] % model_size:
                dim = _pick_dim(shape, model_size, prefer_first=row)
    else:
        dim = _pick_dim(shape, model_size, prefer_first=row)

    spec = [None] * len(shape)
    if dim is not None:
        spec[dim] = model_axis
    return P(*lead, *spec)


def params_sharding(params_shapes, mesh, *, stacked: bool):
    """Pytree of PartitionSpec matching a params pytree (of shapes/arrays)."""
    model_size = mesh.shape["model"]
    l_axes = tuple(a for a in mesh.axis_names if a != "model") if stacked \
        else None
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shapes)
    specs = [leaf_spec(path, leaf, model_size, learner_axes=l_axes)
             for path, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


def batch_sharding(batch_shapes, mesh, *, stacked: bool):
    """Batch leaves: (L, B_local, ...) stacked or (GB, ...) flat.  dim0 over
    the learner axes when divisible; everything else replicated."""
    l_axes = tuple(a for a in mesh.axis_names if a != "model")
    n_l = 1
    for a in l_axes:
        n_l *= mesh.shape[a]

    def one(leaf):
        if leaf.ndim == 0:
            return P()
        if leaf.shape[0] % n_l == 0 and leaf.shape[0] >= n_l:
            return P(l_axes, *([None] * (leaf.ndim - 1)))
        return P(*([None] * leaf.ndim))

    return jax.tree_util.tree_map(one, batch_shapes)


def cache_sharding(cache_shapes, mesh):
    """Decode caches.  Leaves are period-stacked (Np, B, ...).

    Rules (per EXPERIMENTS.md §Perf H3): batch (dim 1) over the learner
    axes; for attention K/V caches (Np, B, W, KV, hd) the TIME dim W is
    sharded over `model` — *sequence-sharded KV cache*.  Sharding the head
    dim instead makes the decode einsum contract over a sharded axis and XLA
    all-gathers the entire cache every layer (measured 97 GB/step for
    mistral-large decode_32k); with W sharded, the only cross-shard traffic
    is the tiny softmax/output reduction.  SSM/conv state tensors shard
    their feature dim over `model`.  slot_pos bookkeeping is replicated.
    """
    model_size = mesh.shape["model"]
    l_axes = tuple(a for a in mesh.axis_names if a != "model")
    n_l = 1
    for a in l_axes:
        n_l *= mesh.shape[a]

    def one(path, leaf):
        name = _path_str(path)
        nd = leaf.ndim
        spec = [None] * nd
        if "slot_pos" in name:
            return P(*spec)
        if nd >= 2 and leaf.shape[1] % n_l == 0 and leaf.shape[1] >= n_l:
            spec[1] = l_axes          # batch dim (after period-stack dim)
        is_attn_kv = nd == 5 or ("xk" in name or "xv" in name)
        if is_attn_kv:
            w_dim = nd - 3            # (..., W, KV, hd)
            if leaf.shape[w_dim] % model_size == 0 \
                    and leaf.shape[w_dim] >= model_size:
                spec[w_dim] = "model"
                return P(*spec)
        # SSM/conv/mLSTM states: biggest divisible trailing dim over model
        for d in (nd - 2, nd - 1):
            if d < 2:
                continue
            if spec[d] is None and leaf.shape[d] % model_size == 0 \
                    and leaf.shape[d] >= model_size:
                spec[d] = "model"
                break
        return P(*spec)

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shapes)
    return jax.tree_util.tree_unflatten(
        treedef, [one(p, l) for p, l in flat])


def named_shardings(spec_tree, mesh):
    """PartitionSpec tree -> NamedSharding tree (jax.jit's in_shardings wants
    concrete Shardings, not bare specs).  None subtrees pass through."""
    from jax.sharding import NamedSharding
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s) if isinstance(s, P) else s, spec_tree)
