"""Roofline extraction from a compiled dry-run artifact.

Three terms per (arch, shape, mesh), all per-chip seconds:

  compute    = HLO_FLOPs / PEAK_FLOPS
  memory     = HLO_bytes / HBM_BW
  collective = link_bytes / ICI_BW

HLO_FLOPs / HLO_bytes come from compiled.cost_analysis() (per-partition
program under SPMD).  link_bytes is parsed from the optimized HLO text:
for each all-reduce / all-gather / reduce-scatter / all-to-all /
collective-permute op we estimate the bytes a single device moves over ICI
using the standard ring-algorithm costs:

  all-reduce       2 * size * (n-1)/n
  all-gather       out_size * (n-1)/n
  reduce-scatter   in_size * (n-1)/n
  all-to-all       size * (n-1)/n
  collective-perm  size

Hardware constants (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", )
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{")


def parse_collectives(hlo_text: str, body_trip_count: int = 1) -> List[Dict]:
    """Per-collective records with estimated per-device link bytes.

    Collectives inside non-ENTRY computations are (by construction of our
    step functions) inside the scan-over-layers while body, which executes
    `body_trip_count` times per step — XLA's text lists the body once, so we
    multiply.  (Inner sequence scans contain no collectives: activations stay
    shard-local inside attention/ssm chunk loops; asserted by tests.)
    """
    out = []
    in_entry = False
    for line in hlo_text.splitlines():
        cm = _COMP_RE.match(line)
        if cm:
            in_entry = cm.group(1) is not None
            continue
        m = _COLL_RE.match(line)
        if m is None:
            continue
        kind = m.group(2)
        # async pairs: count the -start, skip the -done
        if "-done(" in line:
            continue
        out_shape_text = m.group(1)
        out_bytes = _shape_bytes(out_shape_text)
        # operand shapes: everything after the op name's '('
        args = line.split("(", 1)[1]
        in_bytes = _shape_bytes(args.split(")", 1)[0])
        g = _GROUPS_RE.search(line)
        if g:
            n = len(g.group(1).split(","))
        else:
            gi = _GROUPS_IOTA_RE.search(line)
            n = int(gi.group(2)) if gi else 2
        n = max(n, 2)
        if kind == "all-reduce":
            link = 2 * out_bytes * (n - 1) / n
        elif kind == "all-gather":
            link = out_bytes * (n - 1) / n
        elif kind == "reduce-scatter":
            link = in_bytes * (n - 1) / n
        elif kind == "all-to-all":
            link = max(out_bytes, in_bytes) * (n - 1) / n
        else:  # collective-permute
            link = out_bytes
        mult = 1 if in_entry else body_trip_count
        out.append({"kind": kind, "group_size": n, "out_bytes": out_bytes,
                    "in_bytes": in_bytes, "link_bytes": link * mult,
                    "in_loop_body": not in_entry, "trip_mult": mult})
    return out


def remat_ratio(hlo_text: str) -> float:
    """Crude recompute indicator: duplicate fusion count / total fusions."""
    fusions = re.findall(r"%fusion[\w.]*", hlo_text)
    return 0.0 if not fusions else 1.0 - len(set(fusions)) / len(fusions)


@dataclasses.dataclass
class Roofline:
    flops: float
    hbm_bytes: float
    link_bytes: float
    collectives: List[Dict]
    hlo_flops: float = 0.0
    hlo_bytes: float = 0.0

    @property
    def t_compute(self):
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self):
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self):
        return self.link_bytes / ICI_BW

    @property
    def bottleneck(self):
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    def summary(self) -> Dict:
        by_kind: Dict[str, float] = {}
        for c in self.collectives:
            by_kind[c["kind"]] = by_kind.get(c["kind"], 0.0) + c["link_bytes"]
        return {
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "hlo_flops": self.hlo_flops, "hlo_bytes": self.hlo_bytes,
            "link_bytes": self.link_bytes,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "n_collectives": len(self.collectives),
            "link_bytes_by_kind": by_kind,
        }


def roofline_from_compiled(compiled, *, body_trip_count: int = 1,
                           analytic_flops: float | None = None,
                           analytic_bytes: float | None = None) -> Roofline:
    """Roofline terms.  HLO cost_analysis counts while bodies once (verified
    — see EXPERIMENTS.md §Dry-run), so when analytic flops/bytes models are
    provided they take precedence for the compute/memory terms; the raw HLO
    numbers are preserved in hlo_flops / hlo_bytes as a structural check."""
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older jax returns [dict]
        cost = cost[0]
    hlo_flops = float(cost.get("flops", 0.0))
    hlo_bytes = float(cost.get("bytes accessed", 0.0))
    text = compiled.as_text()
    colls = parse_collectives(text, body_trip_count)
    link = sum(c["link_bytes"] for c in colls)
    return Roofline(
        flops=analytic_flops if analytic_flops is not None else hlo_flops,
        hbm_bytes=analytic_bytes if analytic_bytes is not None else hlo_bytes,
        link_bytes=link, collectives=colls,
        hlo_flops=hlo_flops, hlo_bytes=hlo_bytes)


def memory_summary(compiled) -> Dict:
    ma = compiled.memory_analysis()
    keys = ["argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes"]
    out = {}
    for k in keys:
        out[k] = int(getattr(ma, k, 0) or 0)
    out["total_hbm_bytes"] = (out.get("argument_size_in_bytes", 0)
                              + out.get("output_size_in_bytes", 0)
                              + out.get("temp_size_in_bytes", 0)
                              - out.get("alias_size_in_bytes", 0))
    return out
