"""granite-moe-3b-a800m [moe] — hf:ibm-granite/granite-3.0-1b-a400m-base
family per assignment: 40 experts top-8, per-expert d_ff=512."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab=49155,
    n_experts=40,
    experts_per_tok=8,
    moe_every=1,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
    param_dtype="bfloat16", compute_dtype="bfloat16",
)
