"""Config registry: --arch <id> resolves here."""
from .base import ModelConfig
from .gemma2_27b import CONFIG as GEMMA2_27B
from .granite_20b import CONFIG as GRANITE_20B
from .granite_moe_3b_a800m import CONFIG as GRANITE_MOE_3B_A800M
from .jamba_v01_52b import CONFIG as JAMBA_V01_52B
from .mistral_large_123b import CONFIG as MISTRAL_LARGE_123B
from .qwen2_vl_7b import CONFIG as QWEN2_VL_7B
from .qwen3_moe_235b_a22b import CONFIG as QWEN3_MOE_235B_A22B
from .seamless_m4t_large_v2 import CONFIG as SEAMLESS_M4T_LARGE_V2
from .transformer_100m import CONFIG as TRANSFORMER_100M
from .xlstm_350m import CONFIG as XLSTM_350M
from .yi_34b import CONFIG as YI_34B

REGISTRY = {c.name: c for c in [
    MISTRAL_LARGE_123B, SEAMLESS_M4T_LARGE_V2, GEMMA2_27B, GRANITE_20B,
    QWEN3_MOE_235B_A22B, XLSTM_350M, YI_34B, GRANITE_MOE_3B_A800M,
    QWEN2_VL_7B, JAMBA_V01_52B, TRANSFORMER_100M,
]}

ASSIGNED = [c.name for c in [
    MISTRAL_LARGE_123B, SEAMLESS_M4T_LARGE_V2, GEMMA2_27B, GRANITE_20B,
    QWEN3_MOE_235B_A22B, XLSTM_350M, YI_34B, GRANITE_MOE_3B_A800M,
    QWEN2_VL_7B, JAMBA_V01_52B,
]]

# assigned input shapes: (seq_len, global_batch, kind)
SHAPES = {
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32768, 32, "prefill"),
    "decode_32k": (32768, 128, "decode"),
    "long_500k": (524288, 1, "decode"),
}


def get_config(name: str) -> ModelConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch '{name}'; available: {sorted(REGISTRY)}")
    return REGISTRY[name]


__all__ = ["ModelConfig", "REGISTRY", "ASSIGNED", "SHAPES", "get_config"]
