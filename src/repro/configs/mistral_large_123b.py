"""mistral-large-123b [dense] — hf:mistralai/Mistral-Large-Instruct-2407."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mistral-large-123b",
    family="dense",
    n_layers=88,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab=32768,
    rope_theta=1e6,
    attn_pattern="global",      # long_500k serving uses the sliding variant
    window=4096,
    source="hf:mistralai/Mistral-Large-Instruct-2407",
    param_dtype="bfloat16", compute_dtype="bfloat16",
)
