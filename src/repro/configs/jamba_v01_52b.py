"""jamba-v0.1-52b [hybrid] — arXiv:2403.19887.  Period of 8 layers:
attention at offset 4, mamba elsewhere; MoE (16 experts top-2) every 2nd
layer.  No RoPE (mamba carries position)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=65536,
    n_experts=16,
    experts_per_tok=2,
    moe_every=2,
    block_period=8 * ("mamba",),
    attn_layer_offset=4,
    ssm_state=16, ssm_conv=4, ssm_expand=2,
    use_rope=False,
    attn_pattern="sliding",        # jamba attn layers; window for long ctx
    window=4096,
    source="arXiv:2403.19887",
    param_dtype="bfloat16", compute_dtype="bfloat16",
)
