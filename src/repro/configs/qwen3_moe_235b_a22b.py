"""qwen3-moe-235b-a22b [moe] — hf:Qwen/Qwen3-30B-A3B family scaled per
assignment: 128 experts, top-8, per-expert d_ff=1536."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    head_dim=128,
    d_ff=1536,
    vocab=151936,
    n_experts=128,
    experts_per_tok=8,
    moe_every=1,
    rope_theta=1e6,
    source="hf:Qwen/Qwen3-30B-A3B",
    param_dtype="bfloat16", compute_dtype="bfloat16",
)
