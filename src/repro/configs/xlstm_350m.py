"""xlstm-350m [ssm] — arXiv:2405.04517.  Alternating mLSTM / sLSTM blocks
(d_ff=0: the blocks carry their own projections)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    head_dim=256,
    d_ff=0,
    vocab=50304,
    block_period=("mlstm", "slstm"),
    scan_chunk=64,
    use_rope=False,
    source="arXiv:2405.04517",
    param_dtype="bfloat16", compute_dtype="bfloat16",
)
