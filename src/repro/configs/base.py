"""ModelConfig — one dataclass drives every assigned architecture.

Families: dense | moe | ssm | hybrid | vlm | audio  (+ 'fc' for the paper's
MNIST net).  Block patterns express heterogeneous stacks (gemma2 local/global
alternation, jamba 1:7 mamba:attention interleave, xlstm mLSTM/sLSTM mix) as
a repeating *period* that is scanned over, keeping the HLO O(1) in depth.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | audio | fc
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0              # 0 -> d_model // n_heads
    source: str = ""               # citation per assignment

    # --- attention ----------------------------------------------------------
    attn_pattern: str = "global"   # global | local_global | sliding
    window: int = 4096
    attn_softcap: float = 0.0      # gemma2: 50.0
    final_softcap: float = 0.0     # gemma2: 30.0
    rope_theta: float = 1e4
    mrope_sections: Tuple[int, ...] = ()   # qwen2-vl M-RoPE (t, h, w)
    attn_chunk: int = 1024         # q/k chunking of the jnp reference path

    # --- MoE ------------------------------------------------------------------
    n_experts: int = 0
    experts_per_tok: int = 0
    moe_every: int = 1             # MoE FFN every k-th layer (jamba: 2)
    capacity_factor: float = 1.25
    moe_backend: str = "einsum"    # einsum | shard_map (explicit all-to-all)

    # --- SSM / xLSTM ----------------------------------------------------------
    block_period: Tuple[str, ...] = ()   # e.g. 8*('mamba',) with attn override
    attn_layer_offset: int = -1    # jamba: index within period that is attention
    ssm_state: int = 16
    ssm_conv: int = 4
    ssm_expand: int = 2
    scan_chunk: int = 64           # remat chunk for recurrent scans

    # --- enc-dec / frontends ----------------------------------------------------
    enc_layers: int = 0            # >0 => encoder-decoder (seamless)
    modality: str = "text"         # text | audio | vision
    n_frontend_tokens: int = 1024  # stub embedding count for audio/vision

    use_rope: bool = True          # jamba: False (mamba provides position)
    use_pallas: bool = False       # route attention through the Pallas kernel

    # --- numerics ----------------------------------------------------------------
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    vocab_pad: int = 256           # pad vocab to a multiple (sharding-friendly)

    # ------------------------------------------------------------------------
    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        return _round_up(self.vocab, self.vocab_pad)

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    def n_params(self) -> int:
        """Analytic parameter count (for 6ND model-flops in the roofline)."""
        d, h, kv, hd, ff, v = (self.d_model, self.n_heads, self.n_kv_heads,
                               self.head_dim_, self.d_ff, self.padded_vocab)
        attn = d * h * hd + 2 * d * kv * hd + h * hd * d
        if self.family in ("ssm",):
            # xlstm: mLSTM/sLSTM blocks (see models/xlstm.py)
            per_layer = self._xlstm_params()
        elif self.family == "hybrid":
            per_layer = self._hybrid_params()
        else:
            mlp = 3 * d * ff
            if self.n_experts:
                moe = self.n_experts * 3 * d * ff + d * self.n_experts
                frac_moe = 1.0 / self.moe_every
                mlp = frac_moe * moe + (1 - frac_moe) * mlp
            per_layer = attn + mlp + 2 * d
        total = self.n_layers * per_layer + v * d * (1 if self.tie_embeddings else 2)
        if self.enc_layers:
            # encoder layers + cross-attention in decoder
            total += self.enc_layers * (attn + 3 * d * ff + 2 * d)
            total += self.n_layers * attn  # cross-attn
        return int(total)

    def n_active_params(self) -> int:
        """Active (per-token) params — MoE counts only top-k experts."""
        if not self.n_experts:
            return self.n_params()
        d, ff = self.d_model, self.d_ff
        total_moe = self.n_layers / self.moe_every * (self.n_experts * 3 * d * ff)
        active_moe = self.n_layers / self.moe_every * (self.experts_per_tok * 3 * d * ff)
        return int(self.n_params() - total_moe + active_moe)

    def _xlstm_params(self) -> int:
        d = self.d_model
        # average of mLSTM (qkv + gates + out, expand 2) and sLSTM block params
        m = 2 * d * 2 * d + 3 * 2 * d + 2 * d * d + d * 2 * d  # rough
        s = 4 * (d * d + d * d) + 2 * d * 4 * d
        return (m + s) // 2 + 2 * d

    def _hybrid_params(self) -> int:
        d, ff = self.d_model, self.d_ff
        di = self.ssm_expand * d
        mamba = 2 * d * di + di * self.ssm_conv + di * (
            2 * self.ssm_state + di // 16) + di * d
        attn = (self.n_heads + 2 * self.n_kv_heads) * self.head_dim_ * d \
            + self.n_heads * self.head_dim_ * d
        n_attn = self.n_layers // 8
        n_mamba = self.n_layers - n_attn
        mlp_dense = 3 * d * ff
        mlp_moe = self.n_experts * 3 * d * ff + d * self.n_experts
        n_moe = self.n_layers // self.moe_every if self.moe_every else 0
        mlps = n_moe * mlp_moe + (self.n_layers - n_moe) * mlp_dense
        return (n_mamba * mamba + n_attn * attn + mlps + 2 * d * self.n_layers) \
            // self.n_layers

    def smoke_config(self) -> "ModelConfig":
        """Reduced same-family variant for CPU smoke tests: <=2 (periods of)
        layers, d_model<=256, <=4 experts."""
        period = max(len(self.block_period), 1)
        n_layers = min(2 * period, self.n_layers)
        if self.family == "hybrid":
            n_layers = period  # one full jamba period exercises every block kind
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=n_layers,
            enc_layers=min(2, self.enc_layers) if self.enc_layers else 0,
            d_model=min(256, self.d_model),
            n_heads=4, n_kv_heads=min(4, max(1, self.n_kv_heads)),
            head_dim=64,
            d_ff=min(512, self.d_ff) if self.d_ff else 0,
            vocab=min(512, self.vocab),
            mrope_sections=(8, 12, 12) if self.mrope_sections else (),
            n_experts=min(4, self.n_experts) if self.n_experts else 0,
            experts_per_tok=min(2, self.experts_per_tok) if self.experts_per_tok else 0,
            window=64,
            attn_chunk=32,
            scan_chunk=8,
            n_frontend_tokens=8,
            param_dtype="float32", compute_dtype="float32",
        )
