"""~100M-param dense LM for the end-to-end CPU training example (deliverable
(b)): 12L, d=768, 12H — GPT-2-small-like but llama-style (RMSNorm+RoPE+SwiGLU)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="transformer-100m",
    family="dense",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    head_dim=64,
    d_ff=2048,
    vocab=32768,
    attn_chunk=256,
    source="paper-scale example (deliverable b)",
)
