"""seamless-m4t-large-v2 [audio] — arXiv:2308.11596.  Enc-dec transformer
backbone; the speech frontend (mel + conformer feature extractor) is a stub
per the assignment carve-out: input_specs provides frame embeddings."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    n_layers=24,            # decoder layers
    enc_layers=24,          # encoder layers (model card: 24/24)
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab=256206,
    modality="audio",
    source="arXiv:2308.11596",
    param_dtype="bfloat16", compute_dtype="bfloat16",
)
