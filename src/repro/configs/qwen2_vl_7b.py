"""qwen2-vl-7b [vlm] — arXiv:2409.12191.  M-RoPE (t/h/w sections); the
ViT vision tower is a stub per the assignment carve-out — input_specs
provides patch embeddings."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    head_dim=128,
    d_ff=18944,
    vocab=152064,
    mrope_sections=(16, 24, 24),   # halves of head_dim 128
    rope_theta=1e6,
    n_frontend_tokens=1024,
    modality="vision",
    source="arXiv:2409.12191",
    param_dtype="bfloat16", compute_dtype="bfloat16",
)
