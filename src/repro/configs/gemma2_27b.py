"""gemma2-27b [dense] — arXiv:2408.00118.  Local/global alternating
attention, attn + final logit soft-capping."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b",
    family="dense",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=36864,
    vocab=256000,
    attn_pattern="local_global",
    window=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    tie_embeddings=True,
    source="arXiv:2408.00118",
    param_dtype="bfloat16", compute_dtype="bfloat16",
)
