"""granite-20b [dense] — arXiv:2405.04324.  Llama-arch code model; MQA
(single KV head) stresses the KV-cache sharding path."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="granite-20b",
    family="dense",
    n_layers=52,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    head_dim=128,
    d_ff=24576,
    vocab=49152,
    source="arXiv:2405.04324",
    param_dtype="bfloat16", compute_dtype="bfloat16",
)
