"""CLI for the static invariant auditor (``make lint``; DESIGN §16).

    python -m repro.analysis.run               # AST + jaxpr/retrace audits
    python -m repro.analysis.run --ast-only    # jax-free rules only (fast)
    python -m repro.analysis.run --root DIR    # AST pass over a fixture tree
    python -m repro.analysis.run --selftest    # prove the auditor still bites

Exit 0: clean.  Exit 1: findings (or, under ``--selftest``, a rule that
failed to fire on its seeded violation).  Exit 2: the auditor itself broke.

The jaxpr/retrace audits re-exec this module with ``--jaxpr-stage`` under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` so the pjit launch
target sees a real (4, 2) mesh — same subprocess idiom as the launch tests
(the flag only works before the jax import, and the parent may already have
jax loaded with one device).
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys
from pathlib import Path
from typing import List

from .lint import lint_root
from .report import RULES, Finding, format_findings

REPO_ROOT = Path(__file__).resolve().parents[3]
_DEVICE_FLAG = "--xla_force_host_platform_device_count=8"


def _jaxpr_stage() -> int:
    """Run the traced audits over all three hot paths (child process)."""
    os.environ.setdefault("XLA_FLAGS", _DEVICE_FLAG)
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    from .targets import audit_launch, audit_serve, audit_trainer
    findings: List[Finding] = []
    for name, audit in [("trainer", audit_trainer),
                        ("launch", audit_launch),
                        ("serve", audit_serve)]:
        print(f"analysis: auditing {name} ...", flush=True)
        findings += audit()
    if findings:
        print(format_findings(findings))
        return 1
    return 0


def _run_jaxpr_subprocess() -> int:
    env = dict(os.environ)
    env["XLA_FLAGS"] = _DEVICE_FLAG
    env.setdefault("JAX_PLATFORMS", "cpu")
    env["PYTHONPATH"] = os.pathsep.join(
        [str(REPO_ROOT / "src")]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    r = subprocess.run(
        [sys.executable, "-m", "repro.analysis.run", "--jaxpr-stage"],
        env=env, cwd=REPO_ROOT, timeout=1800)
    return r.returncode


def _selftest() -> int:
    """Negative control: the seeded violation fixture must light up every
    AST rule, and toy traced programs must trip each jaxpr rule.  A lint
    pass that has gone blind passes everything — this is the tripwire."""
    failures = []

    fixture = REPO_ROOT / "tests" / "fixtures" / "lint_violations"
    if not fixture.is_dir():
        print(f"selftest: fixture tree missing: {fixture}", file=sys.stderr)
        return 2
    fired = {f.rule for f in lint_root(fixture)}
    for want in ("no-host-sync", "no-id-cache", "kernel-oracle",
                 "design-refs"):
        if want not in fired:
            failures.append(f"AST rule {want!r} did not fire on the "
                            "seeded fixture")

    import jax
    import jax.numpy as jnp
    from .jaxpr_audit import (max_concat_elems, no_host_callback,
                              no_param_concat)

    big = jax.make_jaxpr(
        lambda a, b: jnp.concatenate([a, b]))(jnp.ones(600), jnp.ones(600))
    if not no_param_concat(big, bound=1000, target="selftest"):
        failures.append("no-param-concat missed a seeded 1200-elem concat")
    if max_concat_elems(big) != 1200:
        failures.append("max_concat_elems miscounted the seeded concat")

    cb = jax.make_jaxpr(lambda x: jax.pure_callback(
        lambda v: v, jax.ShapeDtypeStruct((), jnp.float32), x))(1.0)
    if not no_host_callback(cb, target="selftest"):
        failures.append("no-host-callback missed a seeded pure_callback")

    from .retrace import RetraceSentinel
    f = jax.jit(lambda x: x + 1)
    f(jnp.ones(3))
    with RetraceSentinel(f, strict=False) as s:
        f(jnp.ones(4))                       # new shape: a real retrace
    if not s.findings:
        failures.append("no-retrace missed a seeded shape-change retrace")

    if failures:
        print("selftest FAILED:\n  " + "\n  ".join(failures))
        return 1
    print(f"selftest: all {len(RULES)} registered rules bite "
          f"({', '.join(sorted(RULES))})")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.analysis.run",
        description="static invariant auditor (DESIGN §16)")
    ap.add_argument("--root", type=Path, default=None,
                    help="run the AST pass over this tree instead of the "
                         "repo (fixture trees; implies --ast-only)")
    ap.add_argument("--ast-only", action="store_true",
                    help="skip the traced jaxpr/retrace audits")
    ap.add_argument("--selftest", action="store_true",
                    help="verify every rule fires on a seeded violation")
    ap.add_argument("--jaxpr-stage", action="store_true",
                    help=argparse.SUPPRESS)       # internal re-exec entry
    args = ap.parse_args(argv)

    if args.jaxpr_stage:
        return _jaxpr_stage()
    if args.selftest:
        return _selftest()

    root = args.root or REPO_ROOT
    findings = lint_root(root)
    if findings:
        print(format_findings(findings))
        return 1
    print(f"analysis: AST pass clean over {root}")

    if args.ast_only or args.root is not None:
        return 0
    rc = _run_jaxpr_subprocess()
    if rc == 0:
        from . import load_all_rules
        print(f"analysis: clean — {len(load_all_rules())} rules, 0 findings")
    return rc


if __name__ == "__main__":
    sys.exit(main())
