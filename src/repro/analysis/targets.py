"""The repo's audit targets: trainer, launch step, serve decode (DESIGN §16).

Each ``audit_*`` function builds the smallest real instance of one hot
path — the same fixtures the tier-1 tests train/serve for parity — then
runs every applicable jaxpr/donation/retrace rule against it and returns
the findings.  ``make lint`` runs all three through ``repro.analysis.run``
(which re-execs the jaxpr stage under 8 forced host devices so the pjit
target lowers like the launch tests do).

The point of auditing *live* objects rather than golden jaxpr dumps: a rule
here fails when the contract breaks, not when an unrelated refactor perturbs
the trace — the bounds come from the object itself (param store size, cache
pool bytes, schedule live-slot tables), never from frozen constants.
"""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from .jaxpr_audit import (collective_count, donation_honored,
                          no_host_callback, no_param_concat, wire_dtype)
from .report import Finding
from .retrace import RetraceSentinel

__all__ = ["audit_trainer", "audit_launch", "audit_serve", "audit_all"]


def _bytes_of(tree) -> int:
    return int(sum(np.prod(x.shape, dtype=np.int64)
                   * np.dtype(x.dtype).itemsize
                   for x in jax.tree_util.tree_leaves(tree)))


def live_slots(schedule) -> int:
    """Non-padded neighbor slots across a compiled schedule's period — the
    exact collective budget (one permute per slot, leaf count does not
    multiply it; see tests/test_gossip_schedule_launch.py)."""
    n = schedule.n
    idx = np.arange(n)
    return int(sum(
        0 if ((schedule.partners[r, k] == idx).all()
              and not schedule.coefs[r][:, 1 + k].any()) else 1
        for r in range(schedule.period) for k in range(schedule.K)))


# ---------------------------------------------------------------------------
# vmap trainer (the research path)
# ---------------------------------------------------------------------------

def audit_trainer(n: int = 4, hidden: int = 32) -> List[Finding]:
    """Audit the flat fused vmap trainer: ``train_step`` and the
    ``run_steps`` scan driver carry no param-sized concat and no host
    callback; donation survives compilation; stepping, controller scale
    writes, and membership swaps never retrace."""
    from repro.core import AlgoConfig, Membership, MultiLearnerTrainer
    from repro.data import ShardedLoader, TemplateImages
    from repro.models import fcnet
    from repro.optim import scale_by_controller, set_controller_scale, sgd

    loader = ShardedLoader(TemplateImages(), n_learners=n, local_batch=16,
                           seed=0)
    params = fcnet.init_params(jax.random.PRNGKey(0), in_dim=784,
                               hidden=hidden)
    tr = MultiLearnerTrainer(
        fcnet.loss_fn, scale_by_controller(sgd(0.1, momentum=0.9)),
        AlgoConfig(algo="dpsgd", topology="ring", n_learners=n),
        engine="flat")
    st = tr.set_membership(tr.init(jax.random.PRNGKey(1), params),
                           Membership(n))
    batch = loader.batch(0)

    findings: List[Finding] = []
    bound = int(st.params.size) // 100
    for name, jxp in [
            ("trainer.train_step", jax.make_jaxpr(tr._train_step)(st, batch)),
            ("trainer.run_steps", jax.make_jaxpr(tr._run_steps)(
                st, jax.tree_util.tree_map(
                    lambda *xs: jnp.stack(xs),
                    *(loader.batch(i) for i in range(2)))))]:
        findings += no_param_concat(jxp, bound=bound, target=name)
        findings += no_host_callback(jxp, target=name)

    compiled = tr.train_step.lower(st, batch).compile()
    findings += donation_honored(
        compiled, min_bytes=_bytes_of(st.params),
        target="trainer.train_step")

    # warm the cache, then swap every operand the design says is swappable
    st, _ = tr.train_step(st, loader.batch(0))
    with RetraceSentinel(tr.train_step, strict=False,
                         labels=["trainer.train_step"]) as sentinel:
        st, _ = tr.train_step(st, loader.batch(1))
        st = st._replace(opt_state=set_controller_scale(st.opt_state, 0.5))
        st, _ = tr.train_step(st, loader.batch(2))
        mem = Membership(n)
        mem.crash(n - 1)
        st = tr.set_membership(st, mem)           # same-shape table swap
        st, _ = tr.train_step(st, loader.batch(3))
    findings += sentinel.findings
    return findings


# ---------------------------------------------------------------------------
# pjit launch step (the scale path) — needs >= 8 devices
# ---------------------------------------------------------------------------

def audit_launch(arch: str = "transformer-100m") -> List[Finding]:
    """Audit the pjit dpsgd step on a (4, 2) mesh with the ppermute
    backend: collective count == the schedule's live slots (in the jaxpr
    AND the compiled HLO), the wire carries the params' wire dtype, no
    param-sized concat, no host callback, donation honored.

    Requires 8+ devices (``XLA_FLAGS=--xla_force_host_platform_device_count
    =8`` before the jax import); ``repro.analysis.run`` handles that."""
    if len(jax.devices()) < 8:
        raise RuntimeError(
            "audit_launch needs 8 devices — run through `python -m "
            "repro.analysis.run`, which forces the host device count")
    from repro.configs import get_config
    from repro.core.flatstate import flat_meta
    from repro.core.schedule import make_schedule
    from repro.launch import sharding as shd
    from repro.launch.train import (jit_train_step, make_dpsgd_train_step,
                                    train_state_specs, train_state_shardings)

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    cfg = get_config(arch).smoke_config()
    from repro.models.model import build_model
    from repro.optim import sgd
    api = build_model(cfg)
    opt = sgd(0.1, momentum=0.9)
    L = mesh.shape["data"]
    specs = train_state_specs(api, opt, mesh, algo="dpsgd")
    shds = train_state_shardings(specs, mesh, algo="dpsgd")
    bspecs = api.train_batch_spec(8, 64)
    bshd = shd.batch_sharding(bspecs, mesh, stacked=False)
    step = make_dpsgd_train_step(api, opt, mesh, gossip_backend="ppermute")

    sched = make_schedule("ring", L)
    expected = live_slots(sched)
    one_learner = jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype),
        jax.eval_shape(api.init, jax.random.PRNGKey(0)))
    meta = flat_meta(one_learner)
    wire = meta.wire_dtype()

    findings: List[Finding] = []
    jxp = jax.make_jaxpr(step)(specs, bspecs)
    target = "launch.dpsgd_step[ppermute]"
    # the ppermute-flat backend concatenates ONE wire buffer per mix (at
    # most a learner's padded flat size; model sharding only shrinks it) —
    # that's the design.  1.5x that bound catches what must never appear:
    # a fleet-sized (L x) gather or a per-leaf pad-and-concat blowup.
    findings += no_param_concat(
        jxp, bound=3 * meta.padded // 2, target=target)
    findings += no_host_callback(jxp, target=target)
    findings += collective_count(jxp, expected=expected, target=target)
    findings += wire_dtype(jxp, expected=wire, target=target)

    with mesh:
        compiled = jit_train_step(
            step, in_shardings=shd.named_shardings((shds, bshd), mesh),
            out_shardings=shd.named_shardings((shds, None), mesh),
        ).lower(specs, bspecs).compile()
    findings += collective_count(
        jxp, expected=expected, target=target + ".hlo",
        hlo_text=compiled.as_text())
    # the compiled module is the per-device SPMD program: its entry layout
    # (and so the aliased bytes) are the sharded shapes — scale the floor
    findings += donation_honored(
        compiled, min_bytes=_bytes_of(specs.params) // mesh.size,
        target=target)
    return findings


# ---------------------------------------------------------------------------
# serve decode step (the inference path)
# ---------------------------------------------------------------------------

def audit_serve(arch: str = "transformer-100m") -> List[Finding]:
    """Audit the paged decode step: no param-sized concat, no host
    callback, the K/V page pools are donated and aliased in place, and
    admissions / mid-flight joins / evictions never retrace."""
    from repro.configs import get_config
    from repro.models.model import build_model
    from repro.serve import ServeEngine

    cfg = get_config(arch).smoke_config()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    eng = ServeEngine(api, params, n_slots=2, page_size=4, max_len=16)
    S = eng.n_slots
    operands = (params, eng.cache, jnp.zeros((S, 1), jnp.int32),
                jnp.zeros((S,), jnp.int32), jnp.asarray(eng.page_table),
                jnp.zeros((S,), bool))

    findings: List[Finding] = []
    target = f"serve.paged_decode_step[{arch}]"
    jxp = jax.make_jaxpr(api.paged_decode_step)(*operands)
    findings += no_param_concat(
        jxp, bound=max(1, _bytes_of(params) // 4 // 100), target=target)
    findings += no_host_callback(jxp, target=target)

    compiled = eng._step_fn.lower(*operands).compile()
    findings += donation_honored(
        compiled, min_bytes=_bytes_of(eng.cache), target=target)

    eng.warmup()
    with RetraceSentinel(eng._step_fn, strict=False,
                         labels=[target]) as sentinel:
        eng.submit([3, 1, 4], 4)
        for _ in range(3):
            eng.step()
        eng.submit([2, 7], 5)                 # mid-flight join
        eng.submit([5], 3)
        eng.run()
    findings += sentinel.findings
    return findings


def audit_all() -> List[Finding]:
    """Everything, in the order the contracts layer: research trainer,
    launch step, serve engine."""
    return audit_trainer() + audit_launch() + audit_serve()
