"""jax-free AST lint pass (DESIGN §16).

Four repo-specific rules that need no tracing, so they run in milliseconds
with no jax import — the first gate in ``make lint``:

* ``no-host-sync`` — in hot-path modules, ``.item()`` / ``np.asarray`` /
  ``block_until_ready`` must carry an explicit ``# lint: allow-host-sync``
  annotation on the statement.  Hot-path modules are the per-step host
  loops (``HOT_PATHS``); any other file can opt in with a
  ``# lint: hot-path`` marker anywhere in the file.  Setup-time numpy code
  (schedule compilation, topology matrices, checkpoint I/O) is deliberately
  out of scope — ``np`` on host tables is not a device sync.
* ``no-id-cache`` — no dict access keyed by ``id(...)``: CPython reuses
  ids after GC, so an ``id()``-keyed jit cache silently cross-wires
  entries (the PR 7 serve-cache bug this rule pins).
* ``kernel-oracle`` — every kernel module in ``kernels/`` has a ``*_ref``
  oracle in ``ref.py`` named after it and a dispatcher import in
  ``ops.py``.  A kernel nothing can cross-check is untestable by the
  repo's kernel/oracle contract (DESIGN §7).
* ``design-refs`` — every ``DESIGN §N`` reference in code, tests, and docs
  resolves to a ``## §N`` heading in DESIGN.md.

``lint_root(root)`` runs all four over a tree; per-rule entry points take
(path, source) or small inputs so tests can feed fixture programs directly.
"""
from __future__ import annotations

import ast
import re
from pathlib import Path
from typing import Iterable, List, Optional, Sequence

from .report import Finding, rule

__all__ = [
    "HOT_PATHS", "SUPPRESS", "HOT_MARKER", "lint_root",
    "no_host_sync", "no_id_cache", "kernel_oracle", "design_refs",
]

SUPPRESS = "# lint: allow-host-sync"
HOT_MARKER = "# lint: hot-path"

# per-step host loops: the modules where an un-annotated host sync is a
# latency bug, not bookkeeping
HOT_PATHS = (
    "src/repro/serve/engine.py",
    "src/repro/serve/bridge.py",
    "src/repro/core/trainer.py",
    "src/repro/core/faults.py",
    "src/repro/core/flatstate.py",
    "src/repro/launch/train.py",
    "src/repro/kernels/ops.py",
)

# 'fixtures' holds seeded-violation trees (tests/fixtures/lint_violations):
# they are lint SUBJECTS only when passed as the root, never as part of it
_SKIP_DIRS = {".git", "__pycache__", ".pytest_cache", "node_modules",
              ".ruff_cache", "fixtures"}


def _skipped(path: Path, root: Path) -> bool:
    return bool(_SKIP_DIRS.intersection(path.relative_to(root).parts))


def _py_files(root: Path) -> List[Path]:
    return sorted(p for p in root.rglob("*.py") if not _skipped(p, root))


def _parse(path: Path, source: str,
           findings: List[Finding]) -> Optional[ast.AST]:
    try:
        return ast.parse(source)
    except SyntaxError as e:                  # a lint pass must not crash
        findings.append(Finding(
            "no-host-sync", f"{path}:{e.lineno or 0}",
            f"unparseable file: {e.msg}"))
        return None


def _numpy_aliases(tree: ast.AST) -> set:
    """Names bound to the numpy module in this file (``np``, ``numpy``...).
    ``jnp.asarray`` never syncs; only the real-numpy aliases are flagged."""
    out = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name == "numpy":
                    out.add(a.asname or "numpy")
    return out


def _suppressed_lines(source: str) -> set:
    return {i for i, line in enumerate(source.splitlines(), start=1)
            if SUPPRESS in line}


def _node_lines(node: ast.AST) -> range:
    return range(node.lineno, (getattr(node, "end_lineno", None)
                               or node.lineno) + 1)


@rule("no-host-sync",
      ".item()/np.asarray/block_until_ready in a hot-path module must be "
      "annotated '# lint: allow-host-sync' (every sync is a decision)")
def no_host_sync(path, source: str) -> List[Finding]:
    findings: List[Finding] = []
    tree = _parse(path, source, findings)
    if tree is None:
        return findings
    np_names = _numpy_aliases(tree)
    ok_lines = _suppressed_lines(source)

    def flag(node, what):
        if not ok_lines.intersection(_node_lines(node)):
            findings.append(Finding(
                "no-host-sync", f"{path}:{node.lineno}",
                f"{what} in a hot-path module without {SUPPRESS!r}"))

    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        fn = node.func
        if isinstance(fn, ast.Attribute):
            if fn.attr == "item" and not node.args:
                flag(node, ".item() (device->host scalar pull)")
            elif fn.attr == "block_until_ready":
                flag(node, "block_until_ready (full device sync)")
            elif (fn.attr == "asarray" and isinstance(fn.value, ast.Name)
                  and fn.value.id in np_names):
                flag(node, f"{fn.value.id}.asarray on device values "
                           "(host transfer)")
        elif isinstance(fn, ast.Name) and fn.id == "block_until_ready":
            flag(node, "block_until_ready (full device sync)")
    return findings


def _contains_id_call(node: ast.AST) -> bool:
    return any(isinstance(n, ast.Call) and isinstance(n.func, ast.Name)
               and n.func.id == "id" for n in ast.walk(node))


@rule("no-id-cache",
      "no dict access keyed by id(...): CPython reuses ids after GC, so "
      "an id()-keyed cache silently cross-wires entries")
def no_id_cache(path, source: str) -> List[Finding]:
    findings: List[Finding] = []
    tree = _parse(path, source, [])
    if tree is None:
        return findings
    for node in ast.walk(tree):
        if isinstance(node, ast.Subscript) and _contains_id_call(node.slice):
            findings.append(Finding(
                "no-id-cache", f"{path}:{node.lineno}",
                "subscript keyed by id(...) — key the cache by the object "
                "itself (WeakKeyDictionary) or an attribute on it"))
        elif (isinstance(node, ast.Call)
              and isinstance(node.func, ast.Attribute)
              and node.func.attr in ("get", "setdefault", "pop")
              and node.args and _contains_id_call(node.args[0])):
            findings.append(Finding(
                "no-id-cache", f"{path}:{node.lineno}",
                f".{node.func.attr}(id(...)) lookup — key the cache by the "
                "object itself, not its transient id"))
    return findings


def _def_names(path: Path) -> set:
    try:
        tree = ast.parse(path.read_text())
    except (OSError, SyntaxError):
        return set()
    return {n.name for n in ast.walk(tree)
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}


def _relative_imports(path: Path) -> set:
    """Module stems imported via ``from .X import ...`` in ``path``."""
    try:
        tree = ast.parse(path.read_text())
    except (OSError, SyntaxError):
        return set()
    return {n.module for n in ast.walk(tree)
            if isinstance(n, ast.ImportFrom) and n.level == 1 and n.module}


@rule("kernel-oracle",
      "every kernel module in kernels/ has a *_ref oracle in ref.py and a "
      "dispatcher import in ops.py (an uncheckable kernel is untestable)")
def kernel_oracle(kernels_dir) -> List[Finding]:
    kernels_dir = Path(kernels_dir)
    findings: List[Finding] = []
    ref_py, ops_py = kernels_dir / "ref.py", kernels_dir / "ops.py"
    for req in (ref_py, ops_py):
        if not req.exists():
            findings.append(Finding(
                "kernel-oracle", str(kernels_dir),
                f"kernels package has no {req.name}"))
    oracle_names = {n for n in _def_names(ref_py) if n.endswith("_ref")}
    dispatched = _relative_imports(ops_py)
    for mod in sorted(kernels_dir.glob("*.py")):
        stem = mod.stem
        if stem in ("__init__", "ops", "ref"):
            continue
        if not any(stem in name for name in oracle_names):
            findings.append(Finding(
                "kernel-oracle", str(mod),
                f"kernel module {stem!r} has no '*{stem}*_ref' oracle in "
                "ref.py"))
        if stem not in dispatched:
            findings.append(Finding(
                "kernel-oracle", str(mod),
                f"kernel module {stem!r} is not imported by the ops.py "
                "dispatcher"))
    return findings


_REF_RE = re.compile(r"DESIGN(?:\.md)?\s+§(\d+)")
_HEADING_RE = re.compile(r"^##\s+§(\d+)\b", re.M)


@rule("design-refs",
      "every 'DESIGN §N' reference in code and docs resolves to a '## §N' "
      "heading in DESIGN.md")
def design_refs(root, files: Optional[Iterable[Path]] = None
                ) -> List[Finding]:
    root = Path(root)
    design = root / "DESIGN.md"
    sections = (set(_HEADING_RE.findall(design.read_text()))
                if design.exists() else set())
    if files is None:
        files = [p for pat in ("*.py", "*.md")
                 for p in root.rglob(pat)
                 if not _skipped(p, root) and p.name != "DESIGN.md"]
    findings: List[Finding] = []
    for path in sorted(files):
        try:
            text = Path(path).read_text()
        except (OSError, UnicodeDecodeError):
            continue
        for i, line in enumerate(text.splitlines(), start=1):
            for sec in _REF_RE.findall(line):
                if sec not in sections:
                    findings.append(Finding(
                        "design-refs", f"{path}:{i}",
                        f"reference to DESIGN §{sec} but DESIGN.md has no "
                        f"'## §{sec}' heading"))
    return findings


def lint_root(root, hot_paths: Optional[Sequence[str]] = None
              ) -> List[Finding]:
    """Run all four AST rules over a repo (or fixture) tree."""
    root = Path(root)
    findings: List[Finding] = []

    hot = {root / p for p in (HOT_PATHS if hot_paths is None else hot_paths)}
    for path in _py_files(root):
        try:
            source = path.read_text()
        except (OSError, UnicodeDecodeError):
            continue
        if path in hot or HOT_MARKER in source:
            findings.extend(no_host_sync(path, source))
        findings.extend(no_id_cache(path, source))

    for kernels_dir in sorted(p for p in root.rglob("kernels")
                              if p.is_dir() and not _skipped(p, root)):
        findings.extend(kernel_oracle(kernels_dir))

    findings.extend(design_refs(root))
    return findings
