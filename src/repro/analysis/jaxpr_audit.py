"""jaxpr-level invariant rules (DESIGN §16).

The engine's performance contracts — no parameter-sized concatenate in the
hot step (PR 3), buffer donation actually honored by XLA (PR 3), one
collective per live neighbor slot (PR 4), bf16 params ship bf16 gossip
(PR 3), no host callback inside a jitted step — were each hand-checked at
least once in an ad-hoc test.  This module turns them into reusable rules
over a traced jaxpr (or a compiled executable, for the contracts only XLA
can vouch for), so any entry point can be audited with one call and CI runs
the whole set before any benchmark (see ``repro.analysis.run``).

Traversal helpers (`iter_eqns`, `count_primitive`, `max_concat_elems`)
recurse into every sub-jaxpr — pjit/closed_call bodies, scan/while carries,
cond branches, custom_jvp/vjp call jaxprs — so a violation cannot hide one
`lax.cond` deep.  ``core.flatstate.max_concat_elems`` is a thin delegate of
the implementation here (the rule framework generalized it; the old import
path keeps working).
"""
from __future__ import annotations

import re
from typing import List, Optional

import jax
import numpy as np

from .report import Finding, rule

__all__ = [
    "iter_eqns", "count_primitive", "primitive_eqns", "max_concat_elems",
    "no_param_concat", "no_host_callback", "collective_count", "wire_dtype",
    "donation_honored", "aliased_param_bytes", "HOST_CALLBACK_PRIMITIVES",
]

try:                                      # jax >= 0.6 moved these
    from jax.extend.core import ClosedJaxpr as _ClosedJaxpr
    from jax.extend.core import Jaxpr as _Jaxpr
except (ImportError, AttributeError):     # pragma: no cover - old jax
    _ClosedJaxpr, _Jaxpr = jax.core.ClosedJaxpr, jax.core.Jaxpr

# primitives that round-trip through the host inside a traced computation:
# one of these in a hot step means a device->host->device sync per call
HOST_CALLBACK_PRIMITIVES = frozenset({
    "pure_callback", "io_callback", "debug_callback", "outside_call",
    "host_callback_call",
})


# ---------------------------------------------------------------------------
# traversal
# ---------------------------------------------------------------------------

def _as_jaxpr(j) -> _Jaxpr:
    return j.jaxpr if isinstance(j, _ClosedJaxpr) else j


def _subjaxprs(v):
    if isinstance(v, _ClosedJaxpr):
        yield v.jaxpr
    elif isinstance(v, _Jaxpr):
        yield v
    elif isinstance(v, (list, tuple)):
        for item in v:
            yield from _subjaxprs(item)


def iter_eqns(jaxpr):
    """Every equation in ``jaxpr`` (Jaxpr or ClosedJaxpr), recursing into
    all nested sub-jaxprs carried in equation params (pjit bodies, scan and
    while carries, cond branch lists, custom_jvp/vjp call jaxprs)."""
    for eqn in _as_jaxpr(jaxpr).eqns:
        yield eqn
        for v in eqn.params.values():
            for sub in _subjaxprs(v):
                yield from iter_eqns(sub)


def primitive_eqns(jaxpr, name: str) -> List:
    """All equations (recursively) whose primitive is ``name``."""
    return [e for e in iter_eqns(jaxpr) if e.primitive.name == name]


def count_primitive(jaxpr, name: str) -> int:
    return len(primitive_eqns(jaxpr, name))


def max_concat_elems(jaxpr) -> int:
    """Largest ``concatenate`` output (in elements) anywhere in the jaxpr.

    The flat engine's contract is that this stays far below the parameter
    count inside a train step: RNG internals emit tiny concats (threefry
    key plumbing), but nothing parameter-sized — the flatten happened once,
    at init.  Returns 0 for a jaxpr with no equations at all (an identity
    program is trivially clean).
    """
    worst = 0
    for eqn in primitive_eqns(jaxpr, "concatenate"):
        for out in eqn.outvars:
            worst = max(worst, int(np.prod(out.aval.shape, dtype=np.int64)))
    return worst


# ---------------------------------------------------------------------------
# rules over a traced jaxpr
# ---------------------------------------------------------------------------

@rule("no-param-concat",
      "no concatenate in the traced step may reach the flat-engine bound "
      "(the per-step re-flatten PR 3 removed must never come back)")
def no_param_concat(jaxpr, *, bound: int, target: str) -> List[Finding]:
    """Flag any concatenate output of ``bound`` elements or more.

    Callers pass ``bound = n_params // 100`` (the tier-1 guard's margin):
    RNG key plumbing concats a handful of words; anything within two orders
    of magnitude of the model is a parameter-sized layout rebuild.
    """
    worst = max_concat_elems(jaxpr)
    if worst >= bound:
        return [Finding(
            "no-param-concat", target,
            f"concatenate of {worst} elems >= bound {bound} — a "
            "parameter-sized flatten is back in the hot step")]
    return []


@rule("no-host-callback",
      "a jitted hot-loop step must not embed host callbacks "
      "(pure/io/debug_callback force a device->host sync per call)")
def no_host_callback(jaxpr, *, target: str) -> List[Finding]:
    out = []
    for eqn in iter_eqns(jaxpr):
        if eqn.primitive.name in HOST_CALLBACK_PRIMITIVES:
            out.append(Finding(
                "no-host-callback", target,
                f"host callback primitive {eqn.primitive.name!r} traced "
                "into the hot step"))
    return out


@rule("collective-count",
      "collectives per step == live neighbor slots in the compiled "
      "GossipSchedule tables (padding slots must cost nothing)")
def collective_count(jaxpr, *, expected: int, target: str,
                     primitive: str = "ppermute",
                     hlo_text: Optional[str] = None) -> List[Finding]:
    """Count gossip collectives against the schedule's live-slot total.

    With ``hlo_text`` the count is taken from the compiled executable
    (``collective-permute`` ops, async ``-start`` forms included) — what
    actually runs; otherwise from the traced jaxpr's ``primitive`` eqns.
    Both too many (leaf-multiplied or padded-slot traffic) and too few
    (a silently-elided mix) are violations.
    """
    if hlo_text is not None:
        got = len(re.findall(r"collective-permute(?:-start)?\(", hlo_text))
        src = "compiled HLO"
    else:
        got = count_primitive(jaxpr, primitive)
        src = f"jaxpr {primitive!r}"
    if got != expected:
        return [Finding(
            "collective-count", target,
            f"{got} collectives in {src}, schedule tables say {expected} "
            "live neighbor slots")]
    return []


@rule("wire-dtype",
      "gossip collectives ship the params' own wire dtype — a bf16 model "
      "must not move f32 over the links")
def wire_dtype(jaxpr, *, expected, target: str,
               primitive: str = "ppermute") -> List[Finding]:
    expected = np.dtype(expected)
    out = []
    for eqn in primitive_eqns(jaxpr, primitive):
        for v in eqn.invars:
            aval = getattr(v, "aval", None)
            if aval is None or not hasattr(aval, "dtype"):
                continue
            if np.dtype(aval.dtype) != expected:
                out.append(Finding(
                    "wire-dtype", target,
                    f"{primitive} ships {np.dtype(aval.dtype).name}, wire "
                    f"dtype is {expected.name} — "
                    f"{np.dtype(aval.dtype).itemsize}x"
                    f"{int(np.prod(aval.shape, dtype=np.int64))} B on the "
                    "links instead of "
                    f"{expected.itemsize}x that"))
    return out


# ---------------------------------------------------------------------------
# donation: only the compiled executable can vouch for this one
# ---------------------------------------------------------------------------

_HLO_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2,
              "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
              "f64": 8, "c64": 8, "c128": 16}


def _split_top_level(s: str) -> List[str]:
    parts, cur, depth = [], [], 0
    for ch in s:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            parts.append("".join(cur).strip())
            cur = []
        else:
            cur.append(ch)
    tail = "".join(cur).strip()
    if tail:
        parts.append(tail)
    return parts


def _param_bytes(hlo_text: str) -> List[int]:
    """Per-parameter byte sizes from the compiled module's entry layout."""
    m = re.search(r"entry_computation_layout=\{\((.*?)\)->", hlo_text, re.S)
    if m is None:
        return []
    out = []
    for part in _split_top_level(re.sub(r"/\*.*?\*/", "", m.group(1))):
        t = re.match(r"([a-z]+[0-9]*)\[([0-9,]*)\]", part.strip())
        if t is None:
            out.append(0)
            continue
        dtype, dims = t.group(1), t.group(2)
        elems = 1
        if dims:
            elems = int(np.prod([int(d) for d in dims.split(",")],
                                dtype=np.int64))
        out.append(elems * _HLO_BYTES.get(dtype, 4))
    return out


def aliased_param_bytes(compiled) -> int:
    """Total bytes of input parameters the compiled executable aliases to
    outputs (``input_output_alias`` in the post-compile HLO) — the bytes XLA
    will actually update in place when the caller donates them."""
    txt = compiled.as_text()
    m = re.search(r"input_output_alias=\{(.*?)\}\s*,\s*entry", txt, re.S)
    if m is None:
        return 0
    sizes = _param_bytes(txt)
    total = 0
    for pm in re.finditer(r"\}:\s*\((\d+)", m.group(1)):
        idx = int(pm.group(1))
        total += sizes[idx] if idx < len(sizes) else 0
    return total


@rule("donation-honored",
      "donate_argnums must survive compilation: the compiled executable "
      "aliases at least the model-sized state buffers in place")
def donation_honored(compiled, *, min_bytes: int,
                     target: str) -> List[Finding]:
    """``compiled`` is a ``jax.stages.Compiled`` (``jit(...).lower(
    ...).compile()``).  ``min_bytes`` is the state volume the caller knows
    must be updated in place (e.g. the (n, T, 128) parameter store, or a
    serve engine's K/V page pools); anything less means XLA silently
    double-buffers model-sized state — the regression PR 3 pinned by hand.
    """
    got = aliased_param_bytes(compiled)
    if got < min_bytes:
        return [Finding(
            "donation-honored", target,
            f"compiled executable aliases {got} B of donated inputs, "
            f"expected >= {min_bytes} B — donation dropped, model-sized "
            "state is double-buffered")]
    return []
