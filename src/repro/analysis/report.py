"""Finding/rule plumbing for the static invariant auditor (DESIGN §16).

jax-free on purpose: the AST lint pass and the CLI's reporting layer import
this without paying (or requiring) a jax import.  Every rule implemented in
``jaxpr_audit``/``retrace``/``lint`` registers itself here with a one-line
contract, so the rule catalog the docs promise is generated from the code
that enforces it — a rule cannot exist without a catalog entry and vice
versa.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict

__all__ = ["Finding", "RULES", "rule", "format_findings"]


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation.  ``where`` is a ``file:line`` location for AST
    findings and an audit-target name (``trainer.train_step``, ...) for
    jaxpr/retrace findings."""
    rule: str
    where: str
    message: str

    def __str__(self) -> str:
        return f"{self.where}: [{self.rule}] {self.message}"


# rule name -> one-line contract (the catalog DESIGN §16 documents)
RULES: Dict[str, str] = {}


def rule(name: str, contract: str) -> Callable:
    """Register a rule implementation under ``name``.

    The decorated callable returns ``list[Finding]`` (empty == clean).
    Names are unique: two implementations claiming one name is a bug in the
    auditor itself, so it raises instead of silently shadowing.
    """
    def deco(fn):
        if name in RULES and RULES[name] != contract:
            raise ValueError(f"rule {name!r} registered twice")
        RULES[name] = contract
        fn.rule_name = name
        return fn
    return deco


def format_findings(findings) -> str:
    lines = [str(f) for f in findings]
    lines.append(f"{len(findings)} finding(s)")
    return "\n".join(lines)
