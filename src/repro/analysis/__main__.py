import sys

from .run import main

sys.exit(main())
