"""Static invariant auditor: jaxpr rules, retrace sentinel, AST lint
(DESIGN §16).  jax-free at import time — the traced-rule modules
(``jaxpr_audit``, ``retrace``, ``targets``) import jax only when used, so
``repro.analysis.lint`` stays a millisecond import for editors and CI."""
from .lint import lint_root
from .report import RULES, Finding, format_findings, rule

__all__ = ["Finding", "RULES", "rule", "format_findings", "lint_root",
           "load_all_rules"]


def load_all_rules():
    """Import every rule module (jax included) and return the full
    name -> contract catalog.  DESIGN §16's rule table is this dict."""
    from . import jaxpr_audit, retrace  # noqa: F401  (registration)
    return dict(RULES)
