"""Retrace sentinel (DESIGN §16): compile-count stays fixed across swaps.

The repo's membership tables (PR 8), controller scale writes (PR 5), and
serve admissions (PR 7) are all designed as *operand* changes: new arrays
flow through the same compiled executable, and nothing retraces.  Each of
those designs was pinned by an ad-hoc ``_cache_size()`` assertion in its own
test file; this module formalizes the pattern as a reusable context manager
plus a registered rule, so any "this must not recompile" window reads as

    with RetraceSentinel(trainer.train_step, eng._step_fn) as s:
        trainer.set_membership(ms2)
        trainer.run(...)
    # raises RetraceError (or, in collect mode, yields findings) on growth

``jax.jit`` functions expose the per-function tracing-cache size as
``_cache_size()``; serve's ``_jitted`` wrapper hangs the jitted callable on
the wrapped function (``fn._serve_jitted``), which the sentinel unwraps.
"""
from __future__ import annotations

from typing import List, Sequence

from .report import Finding, rule

__all__ = ["RetraceError", "RetraceSentinel", "compile_count", "no_retrace"]


class RetraceError(AssertionError):
    """A jitted function recompiled inside a sentinel window."""


def _jitted_of(fn):
    # serve/engine.py's _jitted caches the jit'd callable on the raw fn
    return getattr(fn, "_serve_jitted", fn)


def compile_count(fn) -> int:
    """Number of traces held by ``fn``'s jit cache (0 if never called).

    Accepts a ``jax.jit`` result or a function wrapped by serve's
    ``_jitted`` helper.  Raises TypeError for a plain Python function —
    a sentinel watching an un-jitted callable would vacuously pass.
    """
    j = _jitted_of(fn)
    sz = getattr(j, "_cache_size", None)
    if sz is None:
        raise TypeError(
            f"{fn!r} has no jit trace cache — pass the jitted callable "
            "(jax.jit result or a serve _jitted-wrapped fn)")
    return sz()


class RetraceSentinel:
    """Assert compile-count is unchanged across a window of operand swaps.

    ``strict=True`` (default) raises RetraceError on exit; ``strict=False``
    collects into ``self.findings`` for the auditor's report path.  Watched
    functions are labeled by their qualname unless ``labels`` is given.
    """

    def __init__(self, *fns, strict: bool = True,
                 labels: Sequence[str] = ()):
        if not fns:
            raise ValueError("RetraceSentinel needs at least one jitted fn")
        self.fns = fns
        self.strict = strict
        self.labels = list(labels) or [
            getattr(_jitted_of(f), "__name__", None)
            or getattr(f, "__name__", repr(f)) for f in fns]
        if len(self.labels) != len(fns):
            raise ValueError("labels must match watched fns")
        self.findings: List[Finding] = []

    def __enter__(self):
        self._before = [compile_count(f) for f in self.fns]
        return self

    def __exit__(self, exc_type, exc, tb):
        if exc_type is not None:      # don't mask the real failure
            return False
        for fn, label, before in zip(self.fns, self.labels, self._before):
            after = compile_count(fn)
            if after != before:
                self.findings.append(Finding(
                    "no-retrace", label,
                    f"compile count {before} -> {after} inside a sentinel "
                    "window — an operand swap triggered a retrace"))
        if self.strict and self.findings:
            raise RetraceError("\n".join(str(f) for f in self.findings))
        return False


@rule("no-retrace",
      "membership table swaps, controller scale writes, and serve "
      "admissions are operand changes: compile count must not grow")
def no_retrace(action, *fns, labels: Sequence[str] = ()) -> List[Finding]:
    """Run ``action()`` under a non-strict sentinel watching ``fns`` and
    return the findings (empty == no retrace)."""
    with RetraceSentinel(*fns, strict=False, labels=labels) as s:
        action()
    return s.findings
