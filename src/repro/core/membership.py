"""Elastic learner membership for the decentralized fleet (DESIGN §15).

Production fleets autoscale: learners join, leave, crash and rejoin
mid-run, but the engine freezes ``n`` into FlatMeta, the schedule tables
and the mesh.  This module makes the learner COUNT elastic without making
any SHAPE elastic: the fleet is allocated at capacity ``N_max`` once, and
liveness is data —

  * :class:`Membership` is the host-side source of truth: the active mask,
    per-learner incarnation counters (bumped on every (re)join so a stale
    straggler from a previous life is distinguishable), per-learner
    ``slow_every`` tick divisors (1 = healthy, k = degraded, huge =
    wedged), and a fleet ``epoch`` that bumps on every change.
  * :class:`MemberState` is the device-side bundle threaded through the
    jitted step as a ``TrainState.members`` OPERAND (never a closed-over
    constant — a jit cache silently reuses stale closure tables, which is
    exactly the bug this design avoids).  A membership change is therefore
    a table/operand swap: same shapes reuse the compiled step, a shape
    change (schedule K/period changed with ``n_active``) retraces once.
  * A dead learner is a permanently-inactive straggler: its row keeps zero
    mixing weight (the fused kernel's ``active`` coefficient column and the
    only-active matching/tables already mask it), its parameter/momentum/
    buffer rows are left QUARANTINED in place for a later rejoin, and the
    masked metrics/consensus exclude it bitwise.
  * :func:`admit` is the state surgery for a (re)join: a fresh joiner
    clones the consensus mean of the live learners into its slot
    (``state_view``/``state_from_view`` keep it engine-agnostic); a
    quarantine rejoin resumes from the parked rows.

The scheduling half lives in :func:`core.schedule.reschedule` (conformant
active-set table embedding) and :func:`core.topology.masked_pair_partners`
(only-active random matching); the fault-injection harness that drives all
of this is :mod:`core.faults`.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import schedule as gsched

__all__ = ["Membership", "MemberState", "HUNG", "admit"]

# a wedged learner: never completes a step again until recovered (the
# supervisor's staleness detector evicts it; 2^30 keeps step % safe in i32)
HUNG = 1 << 30


class MemberState(NamedTuple):
    """Device-side membership bundle — a pytree of jit OPERANDS.

    ``partners``/``coefs`` are the ``reschedule`` tables for elastic
    deterministic-topology DPSGD ((period, K, n) i32 / (period, n, K+1)
    f32); None for randomized matchings (drawn in-step from the mask) and
    for AD-PSGD.
    """
    active: jnp.ndarray        # (n,) bool — live fleet members
    incarnation: jnp.ndarray   # (n,) int32 — bumped per (re)join
    slow_every: jnp.ndarray    # (n,) int32 — completes a step every k ticks
    drop_round: jnp.ndarray    # () bool — this tick's gossip round is dropped
    partners: Any = None
    coefs: Any = None


@dataclasses.dataclass
class Membership:
    """Host-side elastic fleet state (capacity-``N_max``, mutable masks)."""
    capacity: int
    active: Optional[np.ndarray] = None
    incarnation: Optional[np.ndarray] = None
    slow_every: Optional[np.ndarray] = None
    epoch: int = 0               # fleet version: bumps on every change

    def __post_init__(self):
        assert self.capacity >= 1, self.capacity
        if self.active is None:
            self.active = np.ones((self.capacity,), bool)
        self.active = np.asarray(self.active, bool).copy()
        if self.incarnation is None:
            self.incarnation = np.zeros((self.capacity,), np.int32)
        if self.slow_every is None:
            self.slow_every = np.ones((self.capacity,), np.int32)
        self.incarnation = np.asarray(self.incarnation, np.int32).copy()
        self.slow_every = np.asarray(self.slow_every, np.int32).copy()

    # -- queries -------------------------------------------------------------
    @property
    def n_active(self) -> int:
        return int(self.active.sum())

    def active_indices(self) -> np.ndarray:
        return np.flatnonzero(self.active)

    # -- transitions (each bumps the fleet epoch) -----------------------------
    def crash(self, i: int) -> None:
        """Learner ``i`` dies/leaves: permanently-inactive straggler whose
        rows stay quarantined in the state for a possible rejoin."""
        assert 0 <= i < self.capacity, i
        self.active[i] = False
        self.slow_every[i] = 1
        self.epoch += 1

    leave = crash     # a graceful leave and a detected crash mask identically

    def join(self, slot: Optional[int] = None) -> int:
        """Activate an inactive slot (first free one by default); returns
        the slot.  Bumps its incarnation — state surgery is the caller's
        job (:func:`admit`)."""
        if slot is None:
            free = np.flatnonzero(~self.active)
            if free.size == 0:
                raise ValueError("fleet at capacity: no inactive slot")
            slot = int(free[0])
        assert 0 <= slot < self.capacity, slot
        assert not self.active[slot], f"slot {slot} already active"
        self.active[slot] = True
        self.incarnation[slot] += 1
        self.slow_every[slot] = 1
        self.epoch += 1
        return slot

    rejoin = join

    def set_slow(self, i: int, every: int) -> None:
        """Degrade learner ``i`` to one completed step per ``every`` ticks."""
        assert 0 <= i < self.capacity and every >= 1, (i, every)
        self.slow_every[i] = every
        self.epoch += 1

    def hang(self, i: int) -> None:
        """Wedge learner ``i``: it stays a member but never completes a
        step — the supervisor's staleness detector is what evicts it."""
        self.set_slow(i, HUNG)

    def recover(self, i: int) -> None:
        self.set_slow(i, 1)

    # -- device bundle --------------------------------------------------------
    def member_state(self, topology: Optional[str] = None, *,
                     gossip_rounds: int = 1,
                     drop_round: bool = False) -> MemberState:
        """Build the jit-operand bundle for the CURRENT membership.

        ``topology`` (DPSGD): deterministic topologies get their
        ``reschedule`` tables embedded at fleet capacity; randomized
        matchings (and AD-PSGD, which passes None) carry no tables — the
        step draws the only-active matching from the mask.
        """
        partners = coefs = None
        if topology is not None and topology.lower() not in (
                "random_pair", "random_matching"):
            s = gsched.reschedule(topology, self.active,
                                  rounds=gossip_rounds)
            partners = jnp.asarray(s.partners)
            coefs = jnp.asarray(s.coefs)
        return MemberState(
            active=jnp.asarray(self.active),
            incarnation=jnp.asarray(self.incarnation),
            slow_every=jnp.asarray(self.slow_every),
            drop_round=jnp.asarray(drop_round, bool),
            partners=partners, coefs=coefs)


def admit(trainer, state, slot: int, *, mode: str = "consensus"):
    """State surgery for a learner (re)joining at ``slot``.

    ``mode='consensus'``: the joiner clones the consensus mean of the
    currently-ACTIVE learners (per ``state.members.active`` — call this
    BEFORE flipping the slot live in the device state) into its parameter
    and published-buffer rows and gets a freshly-initialized optimizer row
    (momentum from a dead past would be stale curvature; the controller
    scale is rewritten fleet-wide by the next AdaScale/AutoLR update).
    ``mode='quarantine'``: resume from the rows parked at eviction —
    parameters, momentum and published buffer are left untouched.

    Either way the async bookkeeping (age/clock) restarts at zero.  The
    grow/shrink round-trips through ``state_view``/``state_from_view`` so
    the same code serves the flat and pytree engines; the flatten cost is
    paid only at membership changes, never in the step.
    """
    assert mode in ("consensus", "quarantine"), mode
    assert state.members is not None, "admit needs an elastic state"
    if mode == "consensus":
        view = trainer.state_view(state)
        act = jnp.asarray(state.members.active)
        denom = jnp.maximum(jnp.sum(act), 1)

        def clone_row(x):
            m = act.reshape((-1,) + (1,) * (x.ndim - 1))
            mean = jnp.sum(jnp.where(m, x.astype(jnp.float32), 0.0),
                           axis=0) / denom
            return x.at[slot].set(mean.astype(x.dtype))

        params = jax.tree_util.tree_map(clone_row, view.params)
        buffer = view.buffer
        if buffer is not None:     # the joiner publishes its cloned weights
            buffer = jax.tree_util.tree_map(
                lambda b, p: b.at[slot].set(p[slot]), view.buffer, params)
        fresh = trainer.optimizer.init(
            jax.tree_util.tree_map(lambda x: x[slot], params))
        opt = jax.tree_util.tree_map(
            lambda s, f: s.at[slot].set(jnp.asarray(f, s.dtype)),
            view.opt_state, fresh)
        state = trainer.state_from_view(
            view._replace(params=params, opt_state=opt, buffer=buffer))
    if state.age is not None:
        state = state._replace(age=state.age.at[slot].set(0))
    if state.clock is not None:
        state = state._replace(clock=state.clock.at[slot].set(0))
    return state
