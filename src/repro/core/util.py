"""Pytree helpers shared by the multi-learner machinery."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["tree_dot", "tree_norm_sq", "tree_add", "tree_sub", "tree_scale",
           "learner_mean", "learner_var", "masked_learner_mean",
           "masked_learner_var", "tree_zeros_like", "tree_gaussian_like",
           "global_norm"]


def tree_dot(a, b):
    leaves = jax.tree_util.tree_map(
        lambda x, y: jnp.sum(x.astype(jnp.float32) * y.astype(jnp.float32)), a, b)
    return jax.tree_util.tree_reduce(jnp.add, leaves, jnp.float32(0.0))


def tree_norm_sq(a):
    return tree_dot(a, a)


def global_norm(a):
    return jnp.sqrt(tree_norm_sq(a))


def tree_add(a, b):
    return jax.tree_util.tree_map(jnp.add, a, b)


def tree_sub(a, b):
    return jax.tree_util.tree_map(jnp.subtract, a, b)


def tree_scale(s, a):
    return jax.tree_util.tree_map(lambda x: s * x, a)


def tree_zeros_like(a):
    return jax.tree_util.tree_map(jnp.zeros_like, a)


def tree_gaussian_like(key, a, std):
    """iid N(0, std^2) noise with the same structure/shapes as `a` (SSGD*)."""
    leaves, treedef = jax.tree_util.tree_flatten(a)
    keys = jax.random.split(key, len(leaves))
    noisy = [std * jax.random.normal(k, l.shape, l.dtype) for k, l in zip(keys, leaves)]
    return jax.tree_util.tree_unflatten(treedef, noisy)


def learner_mean(stacked):
    """Mean over the leading learner axis of every leaf: w_a = (1/n) sum w_j."""
    return jax.tree_util.tree_map(lambda x: jnp.mean(x, axis=0), stacked)


def learner_var(stacked):
    """sigma_w^2 = Tr(C) summed over all parameters: total variance of the
    learner weights around their mean (the paper's weight-variance instrument)."""
    leaves = jax.tree_util.tree_map(
        lambda x: jnp.sum(jnp.var(x.astype(jnp.float32), axis=0)), stacked)
    return jax.tree_util.tree_reduce(jnp.add, leaves, jnp.float32(0.0))


def _mask_for(active, x):
    return jnp.asarray(active, bool).reshape((-1,) + (1,) * (x.ndim - 1))


def masked_learner_mean(stacked, active):
    """Consensus mean over the ACTIVE learners only (elastic membership).

    ``active``: (n,) bool.  Dead/evicted learners' quarantined rows are
    excluded with ``where`` (never multiplied), so an arbitrary — even
    non-finite — parked row cannot bleed into the consensus (DESIGN §15).
    """
    denom = jnp.maximum(jnp.sum(jnp.asarray(active, bool)), 1)

    def _mean(x):
        s = jnp.sum(jnp.where(_mask_for(active, x),
                              x.astype(jnp.float32), 0.0), axis=0)
        return (s / denom).astype(x.dtype)
    return jax.tree_util.tree_map(_mean, stacked)


def masked_learner_var(stacked, active):
    """sigma_w^2 over the ACTIVE learners only (see masked_learner_mean)."""
    denom = jnp.maximum(jnp.sum(jnp.asarray(active, bool)), 1)

    def _var(x):
        m = _mask_for(active, x)
        xf = jnp.where(m, x.astype(jnp.float32), 0.0)
        mean = jnp.sum(xf, axis=0) / denom
        dev = jnp.where(m, xf - mean[None], 0.0)
        return jnp.sum(jnp.square(dev)) / denom
    leaves = jax.tree_util.tree_map(_var, stacked)
    return jax.tree_util.tree_reduce(jnp.add, leaves, jnp.float32(0.0))
