"""Pytree helpers shared by the multi-learner machinery."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["tree_dot", "tree_norm_sq", "tree_add", "tree_sub", "tree_scale",
           "learner_mean", "learner_var", "tree_zeros_like", "tree_gaussian_like",
           "global_norm"]


def tree_dot(a, b):
    leaves = jax.tree_util.tree_map(
        lambda x, y: jnp.sum(x.astype(jnp.float32) * y.astype(jnp.float32)), a, b)
    return jax.tree_util.tree_reduce(jnp.add, leaves, jnp.float32(0.0))


def tree_norm_sq(a):
    return tree_dot(a, a)


def global_norm(a):
    return jnp.sqrt(tree_norm_sq(a))


def tree_add(a, b):
    return jax.tree_util.tree_map(jnp.add, a, b)


def tree_sub(a, b):
    return jax.tree_util.tree_map(jnp.subtract, a, b)


def tree_scale(s, a):
    return jax.tree_util.tree_map(lambda x: s * x, a)


def tree_zeros_like(a):
    return jax.tree_util.tree_map(jnp.zeros_like, a)


def tree_gaussian_like(key, a, std):
    """iid N(0, std^2) noise with the same structure/shapes as `a` (SSGD*)."""
    leaves, treedef = jax.tree_util.tree_flatten(a)
    keys = jax.random.split(key, len(leaves))
    noisy = [std * jax.random.normal(k, l.shape, l.dtype) for k, l in zip(keys, leaves)]
    return jax.tree_util.tree_unflatten(treedef, noisy)


def learner_mean(stacked):
    """Mean over the leading learner axis of every leaf: w_a = (1/n) sum w_j."""
    return jax.tree_util.tree_map(lambda x: jnp.mean(x, axis=0), stacked)


def learner_var(stacked):
    """sigma_w^2 = Tr(C) summed over all parameters: total variance of the
    learner weights around their mean (the paper's weight-variance instrument)."""
    leaves = jax.tree_util.tree_map(
        lambda x: jnp.sum(jnp.var(x.astype(jnp.float32), axis=0)), stacked)
    return jax.tree_util.tree_reduce(jnp.add, leaves, jnp.float32(0.0))
