"""GossipSchedule: compiled time-varying K-neighbor gossip schedules.

The paper's landscape-dependent noise (and hence the self-adjusting
effective LR, Eq. 3-4) is set by the gossip matrix: sparser, faster-mixing
graphs trade consensus distance against noise scale, and the
topology/staleness schedule is the lever for large-batch convergence
(DecentLaM, Yuan et al. 2021; exponential graphs, Ying et al. 2021).

This module compiles every supported topology — static *and* time-varying —
into one uniform executable form that the fused flat-engine kernel
(kernels/gossip_mix.py, DESIGN §11/§12) consumes directly:

    per round r:  partners[r]  (K, n) int32   neighbor index table
                  coefs[r]     (n, K+1) f32   [self, neighbor...] weights

A *round* is one neighbor-gather mix ``w_i <- c_i0 w_i + sum_k c_ik
w_{partners[k,i]}``; a *step* executes ``rounds_per_step`` rounds (multi-round
mixing) and the whole cycle repeats with period ``period``.  K is static
(rounds with fewer neighbors are padded with zero-weight self-loops), so one
compiled kernel serves the entire schedule.  Deterministic schedules
additionally guarantee every partner row is a permutation of ``range(n)``
(``perm_rounds``), which is exactly the form ``jax.lax.ppermute`` needs — the
SPMD launch path derives its collective-permute sequence from the same
tables (core/dpsgd.mix_ppermute_schedule*).

Supported schedules (``make_schedule``):

  ring            static, K=2 (K=1 at n=2): self 1/3, both ring neighbors 1/3.
  torus           static, K=4: 2-D torus shifts, weight 1/5 each.
  full            compiled to K rounds: power-of-two n runs the hypercube
                  matching sequence (log2 n rounds of pairwise averaging whose
                  product is EXACTLY the 1/n all-to-all matrix); other n run a
                  single K=n-1 round with uniform 1/n weights.
  hierarchical    2 rounds (paper App. F): intra-group full average, then the
                  ring-of-groups mix; the product equals
                  topology.hierarchical_matrix == kron(ring(S), J_g/g).
  exp             static exponential graph: neighbors (i + 2^j) mod n for
                  j < ceil(log2 n); self 1/2, each neighbor 1/(2*tau).
                  Doubly stochastic (circulant), not symmetric in general.
  one_peer_exp    one-peer exponential: round t averages with the single
                  neighbor (i + 2^(t mod tau)) mod n with weight 1/2.  Its
                  per-round matrices AVERAGE to the static `exp` matrix over
                  one period (pinned by the conformance suite).
  random_pair     the paper's production recipe: a fresh random perfect
                  matching each step (K=1), drawn from the step key.
  random_matching random_pair with ``rounds`` rounds of multi-round mixing
                  per step (each round redraws the matching).
  solo            no mixing — ``make_schedule`` returns None.

Every realized per-step mixing matrix is doubly stochastic; ``symmetric``
records whether it is also symmetric (checked numerically at compile time
for deterministic schedules).  ``spectral_gap_profile`` measures the actual
consensus contraction of a schedule over a window against the product of
per-step 1-λ₂ bounds — the number benchmarks/ablation_topology.py reports.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import topology as topo

__all__ = ["GossipSchedule", "make_schedule", "reschedule",
           "spectral_gap_profile", "SCHEDULED_TOPOLOGIES",
           "DETERMINISTIC_TOPOLOGIES"]

# every topology make_schedule compiles (solo compiles to None on purpose)
SCHEDULED_TOPOLOGIES = ("full", "ring", "torus", "random_pair",
                        "hierarchical", "exp", "one_peer_exp",
                        "random_matching")
DETERMINISTIC_TOPOLOGIES = ("full", "ring", "torus", "hierarchical", "exp",
                            "one_peer_exp")


@dataclasses.dataclass(frozen=True, eq=False)
class GossipSchedule:
    """Compiled schedule: static metadata + per-round index/coef tables.

    ``eq=False``: instances hold ndarrays and are identity-compared; jitted
    steps close over them (the tables are constants, never traced operands
    except through ``jnp.asarray`` indexing).
    """
    name: str
    n: int
    K: int                     # static neighbor count (self-loop padded)
    period: int                # distinct rounds in the repeating cycle
    rounds_per_step: int       # rounds executed per train step
    randomized: bool           # matchings drawn from the step key
    symmetric: bool            # every realized per-STEP matrix symmetric
    perm_rounds: bool          # every partner row is a permutation (ppermute)
    partners: np.ndarray       # (period, K, n) int32
    coefs: np.ndarray          # (period, n, K+1) f32
    step_mats: Optional[np.ndarray]  # (variants, n, n) f32; None if randomized
    # elastic membership (``reschedule``): ``n`` is the fleet CAPACITY and
    # ``active`` marks the live slots; inactive rows/cols are identity in
    # every realized matrix.  None = the legacy fixed-n schedule.
    active: Optional[np.ndarray] = None   # (n,) bool, or None

    # -- classification -----------------------------------------------------
    @property
    def time_varying(self) -> bool:
        """True when the realized per-step matrix changes across steps.

        A schedule whose step runs a whole number of cycles (ring, torus,
        full-as-rounds, hierarchical, exp) realizes the SAME matrix every
        step and is static; one-peer exponential (one round of a longer
        cycle per step) and the random matchings vary.
        """
        return self.randomized or self.rounds_per_step % self.period != 0

    # -- per-round tables (the fused kernel's operands) ----------------------
    def round_tables(self, key: Optional[jax.Array], r):
        """Tables for global round ``r``: (partners (K, n) i32, coefs
        (n, K+1) f32).  ``r`` may be a traced array for deterministic
        schedules; randomized schedules draw the matching from ``key``
        (round indexing is the caller's job — see ``step_rounds``)."""
        if self.randomized:
            if self.active is None:
                partner = topo.pair_partners(key, self.n)
            else:                 # elastic: only-active random matching
                partner = topo.masked_pair_partners(
                    key, jnp.asarray(self.active))
            solo = partner == jnp.arange(self.n)
            self_c = jnp.where(solo, 1.0, 0.5).astype(jnp.float32)
            return (partner[None].astype(jnp.int32),
                    jnp.stack([self_c, 1.0 - self_c], axis=1))
        if self.period == 1:
            return jnp.asarray(self.partners[0]), jnp.asarray(self.coefs[0])
        idx = r % self.period
        return jnp.asarray(self.partners)[idx], jnp.asarray(self.coefs)[idx]

    def step_rounds(self, key: Optional[jax.Array], step) -> List[Tuple]:
        """All rounds executed at ``step``, in execution order.

        Deterministic schedules index the compiled tables at
        ``(step * rounds_per_step + j) % period`` (a static index whenever
        the step runs whole cycles); randomized ones fold the step key per
        round — round 0 uses the raw key, so a 1-round random matching is
        bit-identical to the legacy ``pair_partners(key, n)`` draw.
        """
        out = []
        for j in range(self.rounds_per_step):
            if self.randomized:
                kj = key if j == 0 else jax.random.fold_in(key, j)
                out.append(self.round_tables(kj, j))
            elif not self.time_varying:
                out.append(self.round_tables(key, j % self.period))
            else:
                out.append(self.round_tables(
                    key, step * self.rounds_per_step + j))
        return out

    # -- matrix realization (einsum fallback path + conformance tests) -------
    def step_matrix(self, key: Optional[jax.Array], step) -> jnp.ndarray:
        """The (n, n) mixing matrix one step realizes (its rounds' product).

        Jit-safe for traced ``step``; this is what the pytree/einsum paths
        multiply by, and what the fused kernel path is parity-tested
        against.
        """
        if self.randomized:
            if self.active is None:     # legacy draw (bitwise-pinned)
                draw = lambda k: topo.random_pair_matrix(k, self.n)  # noqa: E731
            else:
                act = jnp.asarray(self.active)
                draw = lambda k: topo.partner_matrix(  # noqa: E731
                    topo.masked_pair_partners(k, act), self.n)
            m = draw(key)
            for j in range(1, self.rounds_per_step):
                m = draw(jax.random.fold_in(key, j)) @ m
            return m
        mats = jnp.asarray(self.step_mats)
        if self.step_mats.shape[0] == 1:
            return mats[0]
        return mats[step % self.step_mats.shape[0]]

    def mean_matrix(self) -> np.ndarray:
        """Period-average of the per-step matrices (deterministic only) —
        the ergodic mixing matrix a time-varying schedule realizes in
        expectation over its cycle."""
        assert not self.randomized, "randomized schedules have no fixed mean"
        return np.asarray(self.step_mats, np.float64).mean(0)


# ---------------------------------------------------------------------------
# compilation
# ---------------------------------------------------------------------------

def _round_matrix(partners_r: np.ndarray, coefs_r: np.ndarray) -> np.ndarray:
    """(K, n) partners + (n, K+1) coefs -> dense (n, n) f64 mixing matrix."""
    n = partners_r.shape[1]
    m = np.zeros((n, n))
    m[np.arange(n), np.arange(n)] += coefs_r[:, 0].astype(np.float64)
    for k in range(partners_r.shape[0]):
        # each row writes one (i, partner) entry -> plain fancy += is exact
        m[np.arange(n), partners_r[k]] += coefs_r[:, 1 + k].astype(np.float64)
    return m


def _compile(name: str, n: int, rounds: List[Tuple[np.ndarray, np.ndarray]],
             rounds_per_step: int) -> GossipSchedule:
    """Pad per-round tables to a common static K, realize the matrices,
    and validate the schedule contract (double stochasticity, permutation
    rows) once, at compile time."""
    K = max(p.shape[0] for p, _ in rounds)
    period = len(rounds)
    partners = np.tile(np.arange(n, dtype=np.int32), (period, K, 1))
    coefs = np.zeros((period, n, K + 1), np.float32)
    for r, (p, c) in enumerate(rounds):
        kr = p.shape[0]
        partners[r, :kr] = p.astype(np.int32)
        coefs[r, :, 0] = c[:, 0]
        coefs[r, :, 1:1 + kr] = c[:, 1:]

    perm = all((np.sort(partners[r, k]) == np.arange(n)).all()
               for r in range(period) for k in range(K))
    round_mats = [_round_matrix(partners[r], coefs[r]) for r in range(period)]
    for r, m in enumerate(round_mats):
        assert topo.is_doubly_stochastic(m), (name, r)

    variants = (1 if rounds_per_step % period == 0
                else period // math.gcd(period, rounds_per_step))
    step_mats = []
    for v in range(variants):
        m = np.eye(n)
        for j in range(rounds_per_step):
            m = round_mats[(v * rounds_per_step + j) % period] @ m
        step_mats.append(m)
    step_mats = np.asarray(step_mats)
    symmetric = bool(np.allclose(step_mats, step_mats.transpose(0, 2, 1),
                                 atol=1e-12))
    return GossipSchedule(
        name=name, n=n, K=K, period=period, rounds_per_step=rounds_per_step,
        randomized=False, symmetric=symmetric, perm_rounds=perm,
        partners=partners, coefs=coefs,
        step_mats=step_mats.astype(np.float32))


def _shift_round(n: int, shifts, weights, self_weight: float):
    """Round built from circulant index shifts: partner k of i is
    (i + shifts[k]) % n with weight weights[k]; every row is a shift
    permutation, so the round is ppermute-able by construction."""
    idx = np.arange(n)
    partners = np.stack([(idx + s) % n for s in shifts]).astype(np.int32)
    coefs = np.concatenate(
        [np.full((n, 1), self_weight),
         np.tile(np.asarray(weights, np.float64)[None, :], (n, 1))],
        axis=1).astype(np.float32)
    return partners, coefs


def _ring_rounds(n: int):
    if n == 2:
        return [_shift_round(2, [1], [0.5], 0.5)]
    side = (1.0 - 1.0 / 3.0) / 2.0
    return [_shift_round(n, [1, n - 1], [side, side], 1.0 / 3.0)]


def _torus_rounds(n: int):
    r = int(np.sqrt(n))
    while n % r:
        r -= 1
    rows, cols = r, n // r
    idx = np.arange(n)
    rr, cc = idx // cols, idx % cols
    def grid(dr, dc):
        return (((rr + dr) % rows) * cols + (cc + dc) % cols).astype(np.int32)
    partners = np.stack([grid(1, 0), grid(-1, 0), grid(0, 1), grid(0, -1)])
    coefs = np.full((n, 5), 1.0 / 5.0, np.float32)
    return [(partners, coefs)]


def _full_rounds(n: int):
    if n & (n - 1) == 0:       # hypercube: product of log2 n pairings == 1/n
        idx = np.arange(n)
        out = []
        for b in range(int(math.log2(n))):
            partners = (idx ^ (1 << b)).astype(np.int32)[None]
            coefs = np.full((n, 2), 0.5, np.float32)
            out.append((partners, coefs))
        return out
    return [_shift_round(n, list(range(1, n)), [1.0 / n] * (n - 1), 1.0 / n)]


def _hier_dims(n: int) -> Tuple[int, int]:
    g = int(np.sqrt(n))
    while n % g:
        g -= 1
    return n // g, g            # (n_super, group)


def _hierarchical_rounds(n: int):
    S, g = _hier_dims(n)
    if g == 1:                  # no intra grouping possible: plain ring
        return _ring_rounds(n)
    if S == 1:                  # one group: plain full average
        return _full_rounds(n)
    idx = np.arange(n)
    grp, mem = idx // g, idx % g

    def slot(d, s):
        return (((grp + d) % S) * g + (mem + s) % g).astype(np.int32)

    # round 0: intra-group full average
    intra_p = np.stack([slot(0, s) for s in range(1, g)])
    intra_c = np.full((n, g), 1.0 / g, np.float32)
    # round 1: ring across super-learners, uniform within the remote group
    ring_row = np.asarray(topo.ring_matrix(S), np.float64)[0]
    slots, weights = [], []
    for d in range(S):
        if ring_row[d] <= 0:
            continue
        for s in range(g):
            if d == 0 and s == 0:
                continue        # the self slot
            slots.append(slot(d, s))
            weights.append(ring_row[d] / g)
    inter_p = np.stack(slots)
    inter_c = np.concatenate(
        [np.full((n, 1), ring_row[0] / g),
         np.tile(np.asarray(weights, np.float64)[None, :], (n, 1))],
        axis=1).astype(np.float32)
    return [(intra_p, intra_c), (inter_p, inter_c)]


def _exp_tau(n: int) -> int:
    return max(1, int(math.ceil(math.log2(n))))


def _exp_rounds(n: int):
    tau = _exp_tau(n)
    shifts = [(1 << j) % n for j in range(tau)]
    return [_shift_round(n, shifts, [1.0 / (2 * tau)] * tau, 0.5)]


def _one_peer_exp_rounds(n: int):
    tau = _exp_tau(n)
    return [_shift_round(n, [(1 << j) % n], [0.5], 0.5) for j in range(tau)]


def make_schedule(topology: str, n: int, *,
                  rounds: int = 1) -> Optional[GossipSchedule]:
    """Compile ``topology`` for ``n`` learners; ``rounds`` is the
    multi-round mixing depth for ``random_matching``.  Returns None for
    ``solo`` (and any n <= 1, where every schedule degenerates to the
    identity); raises ValueError for unknown topologies."""
    topology = topology.lower()
    if topology not in SCHEDULED_TOPOLOGIES + ("solo",):
        raise ValueError(f"unknown topology: {topology}")
    if topology == "solo" or n <= 1:
        return None
    if topology in ("random_pair", "random_matching"):
        r = 1 if topology == "random_pair" else max(1, rounds)
        return GossipSchedule(
            name=topology, n=n, K=1, period=1, rounds_per_step=r,
            # each matching is symmetric, but the product of two DIFFERENT
            # matchings is not — only the 1-round step matrix is symmetric
            randomized=True, symmetric=r == 1, perm_rounds=True,
            partners=np.tile(np.arange(n, dtype=np.int32), (1, 1, 1)),
            coefs=np.concatenate([np.ones((1, n, 1), np.float32),
                                  np.zeros((1, n, 1), np.float32)], axis=-1),
            step_mats=None)
    builders = {"ring": _ring_rounds, "torus": _torus_rounds,
                "full": _full_rounds, "hierarchical": _hierarchical_rounds,
                "exp": _exp_rounds, "one_peer_exp": _one_peer_exp_rounds}
    round_list = builders[topology](n)
    # one-peer exponential runs ONE round of its cycle per step (that is
    # the point: O(P) traffic per step); the multi-round compilations
    # (full-as-rounds, hierarchical) execute their whole cycle each step
    rps = 1 if topology == "one_peer_exp" else len(round_list)
    return _compile(topology, n, round_list, rps)


# ---------------------------------------------------------------------------
# elastic membership: recompile a topology onto the live active set
# ---------------------------------------------------------------------------

def _identity_schedule(topology: str, cap: int, active: np.ndarray
                       ) -> GossipSchedule:
    return GossipSchedule(
        name=topology, n=cap, K=1, period=1, rounds_per_step=1,
        randomized=False, symmetric=True, perm_rounds=True,
        partners=np.tile(np.arange(cap, dtype=np.int32), (1, 1, 1)),
        coefs=np.concatenate([np.ones((1, cap, 1), np.float32),
                              np.zeros((1, cap, 1), np.float32)], axis=-1),
        step_mats=np.eye(cap, dtype=np.float32)[None], active=active)


def reschedule(topology: str, active, *, rounds: int = 1) -> GossipSchedule:
    """Recompile ``topology`` for the current active set of a capacity fleet.

    ``active``: (capacity,) bool mask of live learners.  Returns a
    capacity-sized :class:`GossipSchedule` whose realized matrices are the
    identity on the inactive slots and EXACTLY ``make_schedule(topology,
    n_active)``'s matrices on the active set (active-rank i plays physical
    slot ``flatnonzero(active)[i]``) — so every realized matrix stays doubly
    stochastic globally AND restricts to a conformant mixing matrix over
    the live learners (the elastic conformance guarantee, DESIGN §15).

    K is static per (topology, n_active): a membership change is a TABLE
    swap — the elastic trainer threads these tables through the step as jit
    operands (TrainState.members), so a same-shape swap reuses the compiled
    step and a shape change retraces exactly once.  Randomized topologies
    need no tables at all: they return a masked-draw schedule whose
    matching is drawn over the active set inside the step.  A fleet with
    <= 1 live learner (or 'solo') compiles to explicit identity tables
    rather than ``make_schedule``'s None, keeping the operand plumbing
    uniform.
    """
    active = np.ascontiguousarray(np.asarray(active, dtype=bool))
    cap = int(active.shape[0])
    idx = np.flatnonzero(active)
    m = int(idx.size)
    topology = topology.lower()
    if topology not in SCHEDULED_TOPOLOGIES + ("solo",):
        raise ValueError(f"unknown topology: {topology}")
    if topology in ("random_pair", "random_matching") and m > 1:
        r = 1 if topology == "random_pair" else max(1, rounds)
        return GossipSchedule(
            name=topology, n=cap, K=1, period=1, rounds_per_step=r,
            randomized=True, symmetric=r == 1, perm_rounds=True,
            partners=np.tile(np.arange(cap, dtype=np.int32), (1, 1, 1)),
            coefs=np.concatenate([np.ones((1, cap, 1), np.float32),
                                  np.zeros((1, cap, 1), np.float32)],
                                 axis=-1),
            step_mats=None, active=active)
    inner = (None if (topology == "solo" or m <= 1)
             else make_schedule(topology, m, rounds=rounds))
    if inner is None:
        return _identity_schedule(topology, cap, active)
    P, K = inner.period, inner.K
    partners = np.tile(np.arange(cap, dtype=np.int32), (P, K, 1))
    coefs = np.zeros((P, cap, K + 1), np.float32)
    coefs[:, :, 0] = 1.0                        # inactive rows: self-loops
    partners[:, :, idx] = idx[inner.partners]   # active-rank -> physical slot
    coefs[:, idx, :] = inner.coefs
    step_mats = None
    if inner.step_mats is not None:
        V = inner.step_mats.shape[0]
        step_mats = np.tile(np.eye(cap, dtype=np.float32), (V, 1, 1))
        step_mats[np.ix_(np.arange(V), idx, idx)] = inner.step_mats
    return GossipSchedule(
        name=inner.name, n=cap, K=K, period=P,
        rounds_per_step=inner.rounds_per_step, randomized=False,
        symmetric=inner.symmetric, perm_rounds=inner.perm_rounds,
        partners=partners, coefs=coefs, step_mats=step_mats, active=active)


# ---------------------------------------------------------------------------
# analyzer: measured consensus contraction vs the spectral-gap bound
# ---------------------------------------------------------------------------

def spectral_gap_profile(schedule: Optional[GossipSchedule], *,
                         window: int = 0, key: Optional[jax.Array] = None,
                         seed: int = 0, floor: float = 1e-6) -> dict:
    """Measure a schedule's consensus contraction over ``window`` steps.

    For each step matrix M_t the per-step contraction factor on the
    disagreement subspace is eta_t = ||M_t - J||_2 (J = 11^T/n; for a
    symmetric doubly stochastic M this is exactly |λ₂|, so 1 - eta is the
    classical spectral gap).  Submultiplicativity gives the *bound*
    ||Φ - J||_2 <= prod eta_t for the window product Φ; the *measured* rate
    is the actual ||Φ - J||_2^(1/window).  Time-varying schedules typically
    beat their per-step bound — that gap is the point of the analyzer (and
    the `measured_gap >= gap_bound` column in benchmarks/ablation_topology).

    Returns per-step gaps plus geometric-mean rates:
      measured_rate <= bound_rate,  measured_gap = 1 - measured_rate,
      gap_bound = 1 - bound_rate.
    ``schedule=None`` (solo) profiles the identity: no contraction.

    Precision floor: the tables are f32, so a window that mixes below
    ~1e-7 disagreement is unresolvable — the accumulated representation
    noise stops contracting while the exact λ₂-product keeps shrinking,
    which would invert the guaranteed inequality.  Both norms are clamped
    at ``floor`` (default 1e-6) before the W-th root, which preserves
    ``measured_rate <= bound_rate`` on fully-mixed windows and leaves
    slower schedules untouched.
    """
    if schedule is None:
        w = max(window, 1)
        return {"window": w, "per_step_gap": [0.0] * w,
                "measured_rate": 1.0, "bound_rate": 1.0,
                "measured_gap": 0.0, "gap_bound": 0.0}
    # elastic (reschedule) schedules: contraction is defined OVER THE ACTIVE
    # SET — inactive rows are identity by construction (they never couple to
    # a live learner), so the profile restricts every step matrix to the
    # active submatrix, which is exact, and measures consensus there.
    sub = None
    if schedule.active is not None:
        sub = np.flatnonzero(np.asarray(schedule.active, bool))
        if sub.size <= 1:
            w = max(window, 1)
            return {"window": w, "per_step_gap": [0.0] * w,
                    "measured_rate": 1.0, "bound_rate": 1.0,
                    "measured_gap": 0.0, "gap_bound": 0.0}
    n = schedule.n if sub is None else int(sub.size)
    if not window:
        window = max(8, 2 * max(
            1, schedule.period // math.gcd(schedule.period,
                                           schedule.rounds_per_step)))
    if key is None:
        key = jax.random.PRNGKey(seed)
    J = np.full((n, n), 1.0 / n)
    phi = np.eye(n)
    etas, gaps = [], []
    for t in range(window):
        kt = jax.random.fold_in(key, t)
        m = np.asarray(schedule.step_matrix(kt, t), np.float64)
        if sub is not None:
            m = m[np.ix_(sub, sub)]
        phi = m @ phi
        eta = float(np.linalg.norm(m - J, 2))
        etas.append(eta)
        gaps.append(1.0 - eta)
    measured_rate = max(float(np.linalg.norm(phi - J, 2)),
                        floor) ** (1.0 / window)
    bound_rate = max(float(np.prod(etas)), floor) ** (1.0 / window)
    return {"window": window, "per_step_gap": gaps,
            "measured_rate": measured_rate, "bound_rate": bound_rate,
            "measured_gap": 1.0 - measured_rate,
            "gap_bound": 1.0 - bound_rate}
