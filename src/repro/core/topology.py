"""Gossip topologies / mixing matrices for decentralized SGD.

A mixing (gossip) matrix M is row-stochastic (each learner's new weights are a
convex combination of neighbors' weights); for the paper's analysis to hold
(the average weight w_a evolves by the average gradient, Eq. 3) M must be
doubly stochastic.  All matrices produced here are symmetric doubly stochastic.

The paper's production recipe (Sec. 4, App. F): each learner picks a *random
neighbor* each iteration and the pair averages their weights -> a random
perfect-matching permutation-pairing matrix.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "full_matrix",
    "ring_matrix",
    "torus_matrix",
    "pair_partners",
    "masked_pair_partners",
    "partner_matrix",
    "random_pair_matrix",
    "hierarchical_matrix",
    "exponential_matrix",
    "is_doubly_stochastic",
    "spectral_gap",
    "make_mixing_fn",
]


def full_matrix(n: int, dtype=jnp.float32) -> jnp.ndarray:
    """All-to-all averaging: DPSGD degenerates to SSGD weight dynamics."""
    return jnp.full((n, n), 1.0 / n, dtype=dtype)


def ring_matrix(n: int, self_weight: float = 1.0 / 3.0, dtype=jnp.float32) -> jnp.ndarray:
    """Symmetric ring: average with left and right neighbor."""
    if n == 1:
        return jnp.ones((1, 1), dtype)
    if n == 2:
        return jnp.full((2, 2), 0.5, dtype=dtype)
    side = (1.0 - self_weight) / 2.0
    eye = np.eye(n)
    left = np.roll(np.eye(n), 1, axis=1)
    right = np.roll(np.eye(n), -1, axis=1)
    return jnp.asarray(self_weight * eye + side * (left + right), dtype=dtype)


def torus_matrix(rows: int, cols: int, dtype=jnp.float32) -> jnp.ndarray:
    """2D torus: self + 4 neighbors, weight 1/5 each."""
    n = rows * cols
    m = np.zeros((n, n))
    for r in range(rows):
        for c in range(cols):
            i = r * cols + c
            nbrs = [i,
                    ((r + 1) % rows) * cols + c,
                    ((r - 1) % rows) * cols + c,
                    r * cols + (c + 1) % cols,
                    r * cols + (c - 1) % cols]
            for j in nbrs:
                m[i, j] += 1.0 / 5.0
    return jnp.asarray(m, dtype=dtype)


def pair_partners(key: jax.Array, n: int) -> jnp.ndarray:
    """Random perfect matching as a partner-index vector.

    partner[i] == j and partner[j] == i for each matched pair; for odd n one
    learner stays solo that step (partner[i] == i).  This is the paper's
    "randomly pick a neighbor to exchange weights" rule in gather form.
    Built with jnp so it can live inside a jitted train step keyed on the step.
    """
    perm = jax.random.permutation(key, n)
    # pair consecutive entries of the random permutation
    k = (n // 2) * 2
    a = perm[:k:2]
    b = perm[1:k:2]
    partner = jnp.arange(n)
    partner = partner.at[a].set(b)
    partner = partner.at[b].set(a)
    return partner


def masked_pair_partners(key: jax.Array, active, drop=None) -> jnp.ndarray:
    """Random perfect matching over the ACTIVE slots of a capacity-n fleet.

    ``active``: (n,) bool.  Inactive slots are always solo (partner[i] == i)
    and no active slot is ever matched to an inactive one, so a dead
    learner's row carries zero mixing weight without any table recompile —
    the elastic-membership form of :func:`pair_partners` (DESIGN §15).
    Same draw, same key: the active slots are paired consecutively along
    ``pair_partners``'s random permutation with the inactive ones spliced
    out, so an all-active fleet reproduces the legacy matching BITWISE
    (elastic DPSGD/AD-PSGD with nobody dead == the pinned PR 1 trace).
    ``drop`` (scalar bool) forces everyone solo — a dropped gossip round.

    Jit-safe: the active count is a traced value; consecutive-rank pairing
    of a permutation is an involution with only-active pairs by
    construction (odd active count: the last-ranked slot stays solo).
    """
    active = jnp.asarray(active, bool)
    n = active.shape[0]
    idx = jnp.arange(n)
    perm = jax.random.permutation(key, n)
    act_in_order = active[perm]
    # rank of each permutation position among the active entries so far:
    # splicing out the inactive slots keeps the survivors' relative order
    rank = jnp.cumsum(act_in_order) - 1
    m = jnp.sum(active)
    # slot_of_rank[r] = the active slot ranked r (inactive scatters dropped)
    slot_of_rank = jnp.zeros((n,), perm.dtype).at[
        jnp.where(act_in_order, rank, n)].set(perm, mode="drop")
    rank_of_slot = jnp.zeros((n,), rank.dtype).at[perm].set(rank)
    mate_rank = rank_of_slot ^ 1
    paired = active & (mate_rank < m)
    partner = jnp.where(paired, slot_of_rank[mate_rank % n], idx)
    if drop is not None:
        partner = jnp.where(drop, idx, partner)
    return partner


def partner_matrix(partner, n: int, dtype=jnp.float32) -> jnp.ndarray:
    """Dense mixing matrix of an involutive partner vector: 0.5*(I + P).

    Solo rows (partner[i] == i) come out exactly e_i, so the same formula
    covers matched pairs, odd-n leftovers and masked-out (inactive) slots.
    """
    p = jnp.zeros((n, n), dtype).at[jnp.arange(n), partner].set(1.0)
    return 0.5 * (jnp.eye(n, dtype=dtype) + p)


def random_pair_matrix(key: jax.Array, n: int, dtype=jnp.float32) -> jnp.ndarray:
    """Random perfect matching: each learner averages with exactly one partner.

    Implemented as 0.5*(I + P) where P is the involutive pairing permutation
    from :func:`pair_partners` (matrix form of the same matching law).
    """
    partner = pair_partners(key, n)
    p = jnp.zeros((n, n), dtype).at[jnp.arange(n), partner].set(1.0)
    return 0.5 * (jnp.eye(n, dtype=dtype) + p)


def hierarchical_matrix(n_super: int, group: int, inner: str = "full",
                        dtype=jnp.float32) -> jnp.ndarray:
    """Paper App. F: group `group` nearby learners into a super-learner that
    fully averages internally, ring-gossip across super-learners."""
    intra = np.kron(np.eye(n_super), np.full((group, group), 1.0 / group))
    outer = np.asarray(ring_matrix(n_super))
    inter = np.kron(outer, np.full((group, group), 1.0 / group))
    # one intra-average then one inter-ring step; composition stays d.s.
    m = inter @ intra
    return jnp.asarray(m, dtype=dtype)


def exponential_matrix(n: int, dtype=jnp.float32) -> jnp.ndarray:
    """Static exponential graph (Ying et al. 2021): neighbors at offsets
    2^0..2^(tau-1) (tau = ceil(log2 n)), self weight 1/2, each neighbor
    1/(2 tau).  Doubly stochastic (circulant), NOT symmetric in general —
    it is the period-average of the one-peer exponential schedule
    (core/schedule.py), which is how that normalization is pinned."""
    if n <= 1:
        return jnp.ones((1, 1), dtype)
    tau = max(1, int(np.ceil(np.log2(n))))
    m = 0.5 * np.eye(n)
    for j in range(tau):
        m += np.roll(np.eye(n), (1 << j) % n, axis=1) / (2 * tau)
    return jnp.asarray(m, dtype=dtype)


def is_doubly_stochastic(m, atol: float = 1e-5) -> bool:
    m = np.asarray(m, dtype=np.float64)
    return (np.all(m >= -atol)
            and np.allclose(m.sum(0), 1.0, atol=atol)
            and np.allclose(m.sum(1), 1.0, atol=atol))


def spectral_gap(m) -> float:
    """1 - |lambda_2|: convergence rate of the gossip averaging process."""
    ev = np.linalg.eigvals(np.asarray(m, dtype=np.float64))
    ev = np.sort(np.abs(ev))[::-1]
    return float(1.0 - (ev[1] if len(ev) > 1 else 0.0))


def make_mixing_fn(topology: str, n: int):
    """Returns mix_matrix(key, step) -> (n, n) mixing matrix for a step.

    Static topologies ignore the key; 'random_pair' re-draws per step.
    """
    topology = topology.lower()
    if topology == "full":
        m = full_matrix(n)
        return lambda key: m
    if topology == "ring":
        m = ring_matrix(n)
        return lambda key: m
    if topology == "torus":
        r = int(np.sqrt(n))
        while n % r:
            r -= 1
        m = torus_matrix(r, n // r)
        return lambda key: m
    if topology == "random_pair":
        return lambda key: random_pair_matrix(key, n)
    if topology == "hierarchical":
        g = int(np.sqrt(n))
        while n % g:
            g -= 1
        m = hierarchical_matrix(n // g, g) if 1 < g < n else ring_matrix(n)
        return lambda key: m
    if topology == "exp":
        m = exponential_matrix(n)
        return lambda key: m
    if topology == "solo":  # no mixing at all (local SGD w/o averaging)
        m = jnp.eye(n)
        return lambda key: m
    # time-varying schedules (one_peer_exp, random_matching) have no single
    # per-key matrix — compile them with core.schedule.make_schedule instead
    raise ValueError(f"unknown topology: {topology}")
