"""Multi-learner update rules: SSGD, SSGD* and DPSGD (the paper's Eq. 1/2).

All functions operate on *stacked* pytrees whose leaves carry a leading
learner axis of size n.  Two interchangeable gossip backends:

  * ``mix_einsum``   — w_i <- sum_j M_ij w_j, the paper-faithful reference.
    Under pjit the L x L einsum over the learner axis partitions into
    all-gather + local contraction.
  * ``mix_ppermute`` — ring / pairwise gossip via jax.lax.ppermute inside
    shard_map.  Moves O(P) bytes per learner instead of O(L*P): this is the
    TPU-native collective schedule (beyond-paper optimization, see DESIGN §2).

The semantics of one DPSGD step (paper Eq. 2, "mix then descend"):

    g_j   = grad L^{mu_j}(w_j)            # gradient at the LOCAL weights
    w_s,j = sum_k M_jk w_k                # gossip average of neighbors
    w_j   <- w_s,j - alpha * g_j

SSGD (Eq. 1): g_j = grad L^{mu_j}(w_a); w_a <- w_a - alpha * mean_j g_j.
SSGD* adds iid N(0, sigma0^2) weight noise before the gradient evaluation.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

from . import topology as topo
from .util import tree_gaussian_like, learner_mean

__all__ = ["AlgoConfig", "mix_einsum", "mix_ppermute_ring", "mix_ppermute_pair",
           "perturb_weights"]


@dataclasses.dataclass(frozen=True)
class AlgoConfig:
    """How the learners talk to each other."""
    algo: str = "dpsgd"            # dpsgd | ssgd | ssgd_star
    topology: str = "random_pair"  # full | ring | torus | random_pair | solo
    gossip_backend: str = "einsum"  # einsum | ppermute
    gossip_order: str = "mix_then_descend"  # paper Eq. 2; or descend_then_mix
    noise_std: float = 0.01        # sigma_0 for ssgd_star
    n_learners: int = 16

    def __post_init__(self):
        assert self.algo in ("dpsgd", "ssgd", "ssgd_star"), self.algo
        assert self.gossip_order in ("mix_then_descend", "descend_then_mix")
        assert self.gossip_backend in ("einsum", "ppermute")


# ---------------------------------------------------------------------------
# gossip backends
# ---------------------------------------------------------------------------

def mix_einsum(stacked, m):
    """w_i <- sum_j M_ij w_j applied to every leaf (paper-faithful reference)."""
    def _mix(x):
        # ellipsis einsum keeps trailing (model-sharded) dims intact — a
        # flatten here would destroy the tensor-parallel sharding and force
        # XLA to replicate every leaf (measured: 96 GB -> 1.6 GB temp).
        out = jnp.einsum("ij,j...->i...", m.astype(jnp.float32),
                         x.astype(jnp.float32))
        return out.astype(x.dtype)
    return jax.tree_util.tree_map(_mix, stacked)


def mix_ppermute_ring(stacked, axis_names, self_weight: float = 1.0 / 3.0):
    """Symmetric-ring gossip with two collective-permutes over the learner
    mesh axis (to be called inside shard_map; leaves have NO learner dim
    locally — the learner axis is the mesh axis itself)."""
    n = jax.lax.psum(1, axis_names)
    idx = jax.lax.axis_index(axis_names)
    del idx
    fwd = [(i, (i + 1) % n) for i in range(n)]
    bwd = [(i, (i - 1) % n) for i in range(n)]
    side = (1.0 - self_weight) / 2.0

    def _mix(x):
        left = jax.lax.ppermute(x, axis_names, fwd)
        right = jax.lax.ppermute(x, axis_names, bwd)
        return (self_weight * x + side * (left + right)).astype(x.dtype)
    return jax.tree_util.tree_map(_mix, stacked)


def mix_ppermute_pair(stacked, axis_names, step):
    """Pairwise gossip: partner = index XOR (1 << (step % log2 n)) — a
    deterministic hypercube schedule whose per-step matching matches the
    paper's random-neighbor rule in expectation, with ONE collective-permute.
    Call inside shard_map."""
    n = jax.lax.psum(1, axis_names)
    assert n & (n - 1) == 0, "pairwise ppermute gossip needs power-of-two learners"
    import math
    log_n = int(math.log2(n))
    # static schedule per step value is traced; build all log_n permutations and
    # select by step % log_n using lax.switch to stay jittable.
    def make_branch(bit):
        perm = [(i, i ^ (1 << bit)) for i in range(n)]
        def _b(x):
            other = jax.lax.ppermute(x, axis_names, perm)
            return (0.5 * (x + other)).astype(x.dtype)
        return _b

    branches = [make_branch(b) for b in range(log_n)]

    def _mix(x):
        return jax.lax.switch(step % log_n, branches, x)
    return jax.tree_util.tree_map(_mix, stacked)


def perturb_weights(key, params, std):
    """SSGD*: w + delta, delta ~ N(0, std^2 I)."""
    noise = tree_gaussian_like(key, params, std)
    return jax.tree_util.tree_map(jnp.add, params, noise)


def mean_broadcast(stacked):
    """Replace every learner's weights by the global average (SSGD sync)."""
    mean = learner_mean(stacked)
    n = jax.tree_util.tree_leaves(stacked)[0].shape[0]
    return jax.tree_util.tree_map(
        lambda m: jnp.broadcast_to(m[None], (n,) + m.shape), mean)
