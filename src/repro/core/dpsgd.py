"""Multi-learner update rules: SSGD, SSGD*, DPSGD (paper Eq. 1/2) and the
asynchronous AD-PSGD variant (Lian et al. 2018, staleness-bounded model).

All functions operate on *stacked* pytrees whose leaves carry a leading
learner axis of size n.  Two interchangeable gossip backends:

  * ``mix_einsum``   — w_i <- sum_j M_ij w_j, the paper-faithful reference.
    Under pjit the L x L einsum over the learner axis partitions into
    all-gather + local contraction.
  * ``mix_ppermute`` — ring / pairwise gossip via jax.lax.ppermute inside
    shard_map.  Moves O(P) bytes per learner instead of O(L*P): this is the
    TPU-native collective schedule (beyond-paper optimization, see DESIGN §2).

The semantics of one DPSGD step (paper Eq. 2, "mix then descend"):

    g_j   = grad L^{mu_j}(w_j)            # gradient at the LOCAL weights
    w_s,j = sum_k M_jk w_k                # gossip average of neighbors
    w_j   <- w_s,j - alpha * g_j

SSGD (Eq. 1): g_j = grad L^{mu_j}(w_a); w_a <- w_a - alpha * mean_j g_j.
SSGD* adds iid N(0, sigma0^2) weight noise before the gradient evaluation.

AD-PSGD replaces the synchronous pairwise mix by gossip against a possibly
*stale* published weight buffer: each learner averages with one partner's
last-published weights instead of blocking until the partner finishes its
step.  Staleness is bounded (``max_staleness`` ticks) and modeled with an
explicit per-learner clock so the whole thing stays jittable; with
``max_staleness=0`` the buffer is always fresh and AD-PSGD degenerates —
bitwise — to synchronous pairwise DPSGD (asserted in tests).  See DESIGN §3.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from . import topology as topo
from .flatstate import flat_meta
from .util import learner_mean, tree_gaussian_like

__all__ = ["AlgoConfig", "mix_einsum", "mix_ppermute_ring", "mix_ppermute_pair",
           "mix_ppermute_ring_flat", "mix_ppermute_pair_flat",
           "mix_ppermute_schedule", "mix_ppermute_schedule_flat",
           "perturb_weights", "pair_partners", "mix_pair_gather",
           "straggler_active_mask", "member_active_mask"]


@dataclasses.dataclass(frozen=True)
class AlgoConfig:
    """How the learners talk to each other.

    ``topology`` names a compiled GossipSchedule (core/schedule.py):
    static graphs (full | ring | torus | hierarchical | exp), time-varying
    ones (one_peer_exp | random_pair | random_matching), or solo (no
    mixing).  ``gossip_rounds`` is the multi-round mixing depth for
    ``random_matching`` (each round redraws the matching before the
    descent — Stich-style extra mixing for large-batch runs).
    """
    algo: str = "dpsgd"            # dpsgd | ssgd | ssgd_star | adpsgd
    topology: str = "random_pair"  # see core/schedule.SCHEDULED_TOPOLOGIES
    gossip_backend: str = "einsum"  # einsum | ppermute
    gossip_order: str = "mix_then_descend"  # paper Eq. 2; or descend_then_mix
    noise_std: float = 0.01        # sigma_0 for ssgd_star
    n_learners: int = 16
    gossip_rounds: int = 1         # mixing rounds per step (random_matching)
    # -- adpsgd only --------------------------------------------------------
    max_staleness: int = 0         # staleness bound tau (ticks); 0 == sync
    slow_learner: int = -1         # index of the injected straggler (-1: none)
    slow_factor: int = 1           # straggler finishes a step every k ticks

    def __post_init__(self):
        assert self.algo in ("dpsgd", "ssgd", "ssgd_star", "adpsgd"), self.algo
        assert self.gossip_order in ("mix_then_descend", "descend_then_mix")
        assert self.gossip_backend in ("einsum", "ppermute")
        assert self.gossip_rounds >= 1, self.gossip_rounds
        assert self.gossip_rounds == 1 or self.topology == "random_matching", \
            ("gossip_rounds only parameterizes random_matching — other "
             "schedules fix their own round structure (it would be "
             "silently ignored)")
        assert self.max_staleness >= 0, self.max_staleness
        assert self.slow_factor >= 1, self.slow_factor
        assert -1 <= self.slow_learner < self.n_learners, self.slow_learner
        if self.algo == "adpsgd":
            assert self.topology == "random_pair", \
                "adpsgd gossips pairwise; use topology='random_pair'"
            assert self.gossip_order == "mix_then_descend", \
                "adpsgd only supports the paper Eq. 2 ordering"
            assert self.gossip_rounds == 1, \
                "adpsgd's async tick is one pairwise exchange"


# ---------------------------------------------------------------------------
# gossip backends
# ---------------------------------------------------------------------------

def mix_einsum(stacked, m):
    """w_i <- sum_j M_ij w_j applied to every leaf (paper-faithful reference)."""
    def _mix(x):
        # ellipsis einsum keeps trailing (model-sharded) dims intact — a
        # flatten here would destroy the tensor-parallel sharding and force
        # XLA to replicate every leaf (measured: 96 GB -> 1.6 GB temp).
        out = jnp.einsum("ij,j...->i...", m.astype(jnp.float32),
                         x.astype(jnp.float32))
        return out.astype(x.dtype)
    return jax.tree_util.tree_map(_mix, stacked)


def mix_ppermute_ring(stacked, axis_names, self_weight: float = 1.0 / 3.0):
    """Symmetric-ring gossip with two collective-permutes over the learner
    mesh axis (to be called inside shard_map; leaves have NO learner dim
    locally — the learner axis is the mesh axis itself)."""
    n = jax.lax.psum(1, axis_names)
    idx = jax.lax.axis_index(axis_names)
    del idx
    fwd = [(i, (i + 1) % n) for i in range(n)]
    bwd = [(i, (i - 1) % n) for i in range(n)]
    side = (1.0 - self_weight) / 2.0

    def _mix(x):
        left = jax.lax.ppermute(x, axis_names, fwd)
        right = jax.lax.ppermute(x, axis_names, bwd)
        return (self_weight * x + side * (left + right)).astype(x.dtype)
    return jax.tree_util.tree_map(_mix, stacked)


def mix_ppermute_pair(stacked, axis_names, step, remote=None, gate=None):
    """Pairwise gossip: partner = index XOR (1 << (step % log2 n)) — a
    deterministic hypercube schedule whose per-step matching matches the
    paper's random-neighbor rule in expectation, with ONE collective-permute.
    Call inside shard_map.

    ``remote`` (default: ``stacked``) is the tree the partner's contribution
    is read from.  Synchronous pairwise DPSGD exchanges the live weights;
    AD-PSGD passes the stale *published* buffer here so a learner never
    blocks on a partner that is still mid-step (DESIGN §3).

    ``gate`` (scalar 0/1 per shard — elastic membership, DESIGN §15): a
    pair mixes only when BOTH endpoints gate on; otherwise each keeps its
    own weights bitwise (solo).  The gate travels over the same permute,
    so the realized matrix stays symmetric — and doubly stochastic over
    the gated-on (active) set.
    """
    n = jax.lax.psum(1, axis_names)
    assert n & (n - 1) == 0, "pairwise ppermute gossip needs power-of-two learners"
    import math
    log_n = int(math.log2(n))
    if remote is None:
        remote = stacked
    g = None if gate is None else jnp.asarray(gate, jnp.float32)
    # static schedule per step value is traced; build all log_n permutations and
    # select by step % log_n using lax.switch to stay jittable.
    def make_branch(bit):
        perm = [(i, i ^ (1 << bit)) for i in range(n)]
        def _b(xr):
            x, r = xr
            other = jax.lax.ppermute(r, axis_names, perm)
            mixed = (0.5 * (x + other)).astype(x.dtype)
            if g is None:
                return mixed
            both = (g * jax.lax.ppermute(g, axis_names, perm)) > 0.5
            return jnp.where(both, mixed, x)
        return _b

    branches = [make_branch(b) for b in range(log_n)]

    def _mix(x, r):
        return jax.lax.switch(step % log_n, branches, (x, r))
    return jax.tree_util.tree_map(_mix, stacked, remote)


def mix_ppermute_ring_flat(stacked, axis_names, self_weight: float = 1.0 / 3.0):
    """Ring gossip on the flat (T_local, 128) view of the LOCAL shard.

    Same semantics as mix_ppermute_ring, but the whole parameter shard is
    permuted as ONE lane-aligned buffer instead of one collective per leaf:
    2 collective-permutes total.  The buffer is flattened in the params'
    own wire dtype (a uniformly-bf16 model moves 2 bytes/element over the
    links, exactly like the per-leaf path; only a mixed-dtype tree falls
    back to f32), and the averaging arithmetic runs in f32 either way.
    Call inside shard_map; leaves have NO learner dim locally (the learner
    axis is the mesh axis itself).
    """
    meta = flat_meta(stacked)
    v = meta.flatten(stacked, dtype=meta.wire_dtype())
    n = jax.lax.psum(1, axis_names)
    fwd = [(i, (i + 1) % n) for i in range(n)]
    bwd = [(i, (i - 1) % n) for i in range(n)]
    side = (1.0 - self_weight) / 2.0
    left = jax.lax.ppermute(v, axis_names, fwd)
    right = jax.lax.ppermute(v, axis_names, bwd)
    mixed = (self_weight * v.astype(jnp.float32)
             + side * (left.astype(jnp.float32) + right.astype(jnp.float32)))
    return meta.unflatten(mixed)


def mix_ppermute_pair_flat(stacked, axis_names, step, remote=None, gate=None):
    """Pairwise hypercube gossip on the flat (T_local, 128) view.

    Flat-store variant of mix_ppermute_pair: ONE collective-permute moving
    one lane-aligned buffer per step (DESIGN §11), in the params' own wire
    dtype (see mix_ppermute_ring_flat).  ``remote`` is the tree the
    partner's contribution is read from (stale published buffer for
    AD-PSGD; defaults to the live weights).  ``gate``: see
    mix_ppermute_pair — pairs mix only when both endpoints gate on.
    """
    n = jax.lax.psum(1, axis_names)
    assert n & (n - 1) == 0, "pairwise ppermute gossip needs power-of-two learners"
    import math
    log_n = int(math.log2(n))
    meta = flat_meta(stacked)
    wire = meta.wire_dtype()
    v = meta.flatten(stacked, dtype=wire)
    r = v if remote is None else flat_meta(remote).flatten(remote, dtype=wire)
    g = None if gate is None else jnp.asarray(gate, jnp.float32)

    def make_branch(bit):
        perm = [(i, i ^ (1 << bit)) for i in range(n)]

        def _b(xr):
            x, rr = xr
            other = jax.lax.ppermute(rr, axis_names, perm)
            mixed = 0.5 * (x.astype(jnp.float32) + other.astype(jnp.float32))
            if g is None:
                return mixed
            both = (g * jax.lax.ppermute(g, axis_names, perm)) > 0.5
            return jnp.where(both, mixed, x.astype(jnp.float32))
        return _b

    branches = [make_branch(b) for b in range(log_n)]
    mixed = jax.lax.switch(step % log_n, branches, (v, r))
    return meta.unflatten(mixed)


def _schedule_perms(schedule):
    """Per (round, neighbor-slot) ppermute pair lists from a compiled
    deterministic schedule; ``None`` marks a padded self-loop slot (no
    collective is issued for it)."""
    assert not schedule.randomized, \
        "a random matching cannot be a compiled collective schedule"
    assert schedule.perm_rounds, schedule.name
    n = schedule.n
    idx = np.arange(n)
    perms = []
    for r in range(schedule.period):
        slots = []
        for k in range(schedule.K):
            p = np.asarray(schedule.partners[r, k])
            if (p == idx).all() and not schedule.coefs[r][:, 1 + k].any():
                slots.append(None)            # padding: skip the collective
            else:
                # dest i reads partners[k, i] -> perm pairs (src, dst)
                slots.append([(int(p[i]), i) for i in range(n)])
        perms.append(slots)
    return perms


def _schedule_round_mix(x, axis_names, schedule, perms, r: int, idx):
    """One STATIC round ``r`` of the schedule on a local array ``x``:
    gather each neighbor slot with a collective-permute and accumulate in
    f32 with the same term order as the fused kernel/einsum tables."""
    coefs = jnp.asarray(schedule.coefs[r])
    acc = coefs[idx, 0] * x.astype(jnp.float32)
    for k, perm in enumerate(perms[r]):
        if perm is None:
            continue
        other = jax.lax.ppermute(x, axis_names, perm)
        acc = acc + coefs[idx, 1 + k] * other.astype(jnp.float32)
    return acc


def _schedule_mix_rounds(x, axis_names, step, schedule, perms, idx):
    """All rounds one step executes (f32 result).  Whole-cycle schedules
    unroll statically; a time-varying one (one-peer exponential) selects
    its round by ``step`` with lax.switch — same pattern as
    mix_ppermute_pair's hypercube branch table."""
    from functools import partial as _partial
    for j in range(schedule.rounds_per_step):
        if not schedule.time_varying:
            x = _schedule_round_mix(x, axis_names, schedule, perms,
                                    j % schedule.period, idx)
        else:
            r = (step * schedule.rounds_per_step + j) % schedule.period
            branches = [_partial(_schedule_round_mix, axis_names=axis_names,
                                 schedule=schedule, perms=perms, r=rr,
                                 idx=idx)
                        for rr in range(schedule.period)]
            x = jax.lax.switch(r, branches, x)
    return x


def mix_ppermute_schedule(stacked, axis_names, step, schedule):
    """Schedule-driven K-neighbor gossip via collective-permute, per leaf.

    The permutation sequence is derived from the SAME compiled tables the
    fused kernel consumes (every deterministic schedule guarantees each
    partner row is a permutation), so the SPMD path and the research path
    realize the identical mixing matrix — parity-pinned against
    ``schedule.step_matrix`` in tests.  Call inside shard_map; leaves have
    no learner dim locally.
    """
    perms = _schedule_perms(schedule)
    idx = jax.lax.axis_index(axis_names)

    def _mix(x):
        out = _schedule_mix_rounds(x, axis_names, step, schedule, perms, idx)
        return out.astype(x.dtype)
    return jax.tree_util.tree_map(_mix, stacked)


def mix_ppermute_schedule_flat(stacked, axis_names, step, schedule):
    """Flat-store variant of mix_ppermute_schedule (DESIGN §11/§12): one
    lane-aligned (T_local, 128) buffer per collective instead of one
    collective per leaf — K permutes per round regardless of leaf count.
    The first hop moves the params' own wire dtype; multi-round schedules
    keep the running mix in f32 between rounds (the arithmetic is f32
    everywhere, exactly like the per-leaf path)."""
    perms = _schedule_perms(schedule)
    idx = jax.lax.axis_index(axis_names)
    meta = flat_meta(stacked)
    v = meta.flatten(stacked, dtype=meta.wire_dtype())
    out = _schedule_mix_rounds(v, axis_names, step, schedule, perms, idx)
    return meta.unflatten(out)


# ---------------------------------------------------------------------------
# pairwise (matching-based) gossip — shared by sync DPSGD and AD-PSGD
# ---------------------------------------------------------------------------

pair_partners = topo.pair_partners     # re-export: the matching lives with
                                       # the other topology constructors


def mix_pair_gather(stacked, partner, remote=None):
    """w_i <- 0.5 * (w_i + remote[partner_i]); solo learners keep w_i.

    ``remote`` defaults to ``stacked`` (synchronous pairwise DPSGD).  AD-PSGD
    passes the stale published buffer so the partner's contribution may lag
    its live weights by up to the staleness bound.  Solo learners (odd n, or
    partner == self) are left bitwise untouched — critical so a stale *own*
    buffer never bleeds into a learner's weights.
    """
    if remote is None:
        remote = stacked

    def _mix(x, r):
        solo = (partner == jnp.arange(x.shape[0]))
        mask = solo.reshape((-1,) + (1,) * (x.ndim - 1))
        half = 0.5 * (x + r[partner])
        return jnp.where(mask, x, half).astype(x.dtype)
    return jax.tree_util.tree_map(_mix, stacked, remote)


def straggler_active_mask(step, n: int, slow_learner: int, slow_factor: int):
    """(n,) bool: which learners complete a local step this tick.

    The injected straggler (``slow_learner``) takes ``slow_factor`` ticks per
    step, so it is active only when ``step % slow_factor == 0``; everyone else
    is active every tick.  ``slow_learner < 0`` or ``slow_factor == 1``
    disables the injection (all active).
    """
    idx = jnp.arange(n)
    if slow_learner < 0 or slow_factor == 1:
        return jnp.ones((n,), bool)
    return (idx != slow_learner) | (step % slow_factor == 0)


def member_active_mask(step, active, slow_every):
    """(n,) bool: which fleet members complete a local step this tick.

    The elastic generalization of :func:`straggler_active_mask` — instead
    of one statically-configured straggler, every learner carries a dynamic
    ``slow_every`` tick divisor (1 = full speed, k = one completed step per
    k ticks, huge = wedged/hung) and a liveness bit.  Dead learners are
    never active; ``slow_every[i] == straggler``'s ``slow_factor``
    reproduces the legacy injection law exactly (``step % k == 0``).
    All inputs may be traced — this runs inside the jitted step with the
    membership arrays threaded as operands (DESIGN §15).
    """
    slow_every = jnp.asarray(slow_every, jnp.int32)
    gate = (slow_every <= 1) | (step % jnp.maximum(slow_every, 1) == 0)
    return jnp.asarray(active, bool) & gate


def perturb_weights(key, params, std):
    """SSGD*: w + delta, delta ~ N(0, std^2 I)."""
    noise = tree_gaussian_like(key, params, std)
    return jax.tree_util.tree_map(jnp.add, params, noise)


def mean_broadcast(stacked):
    """Replace every learner's weights by the global average (SSGD sync)."""
    mean = learner_mean(stacked)
    n = jax.tree_util.tree_leaves(stacked)[0].shape[0]
    return jax.tree_util.tree_map(
        lambda m: jnp.broadcast_to(m[None], (n,) + m.shape), mean)
