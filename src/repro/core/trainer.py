"""MultiLearnerTrainer — research driver for SSGD / SSGD* / DPSGD / AD-PSGD.

Semantics (paper Sec. 2 + Lian et al. 2018 for the async variant):
  SSGD   : g_j = grad L^{mu_j}(w_a);          w_a <- w_a + opt(mean_j g_j)
  SSGD*  : g_j = grad L^{mu_j}(w_a + delta_j) with delta_j ~ N(0, sigma0^2 I)
  DPSGD  : g_j = grad L^{mu_j}(w_j);          w_j <- mix(w)_j + opt_j(g_j)
  AD-PSGD: like DPSGD with pairwise gossip, but the partner's contribution is
           its last *published* weights (stale by up to ``max_staleness``
           ticks), and an injected straggler only completes a step every
           ``slow_factor`` ticks.  Modeled with explicit per-learner
           buffer/age/clock state so the step stays one jitted function.

Two interchangeable engines (DESIGN §11):

  * ``engine='flat'`` (the default for DPSGD/AD-PSGD) keeps the stacked
    parameters as ONE persistent (n, T, 128) f32 buffer (core/flatstate.py),
    flattened exactly once at init.  Gradients are taken with respect to the
    flat buffer through cheap per-leaf unflatten views, the gossip + SGD
    update runs as the batched Pallas kernel (kernels/ops.flat_gossip_update,
    jnp ``ref`` oracle selectable), and no parameter-sized concatenate ever
    appears in the traced step (guard-tested).
  * ``engine='pytree'`` is the paper-faithful reference: stacked pytrees and
    unfused tree_map updates.  The flat engine is pinned against it by
    parity tests (tests/test_flat_engine.py).

``train_step`` and the ``run_steps`` lax.scan driver donate the state
argument (the old buffers are reused in place — do not touch a state after
passing it in).  Probe/diagnostic jits deliberately do NOT donate: the state
outlives a measurement pass by construction.

This module is the CPU-scale research path (vmap over learners on one
device).  The production pjit/shard_map path lives in repro/launch/train.py
and reuses the same pure update functions.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..optim import Optimizer, apply_updates
from . import schedule as gsched
from . import topology as topo
from .diagnostics import DiagStats, compute_diagnostics
from .dpsgd import (AlgoConfig, mean_broadcast, member_active_mask,
                    mix_einsum, mix_pair_gather, pair_partners,
                    perturb_weights, straggler_active_mask)
from .flatstate import LANE, FlatMeta, flat_meta
from .membership import Membership, MemberState
from .util import (learner_mean, learner_var, masked_learner_mean,
                   masked_learner_var)


class TrainState(NamedTuple):
    params: Any           # stacked: leaves (n, ...) — or (n, T, 128) flat
    opt_state: Any        # stacked per-learner
    step: jnp.ndarray
    rng: jax.Array
    # -- adpsgd only (None otherwise) --------------------------------------
    buffer: Any = None    # last-published weights, stacked like params
    age: Any = None       # (n,) int32 ticks since each learner published
    clock: Any = None     # (n,) int32 completed local steps per learner
    # -- elastic membership (None = legacy fixed fleet; DESIGN §15) --------
    members: Any = None   # MemberState: masks/tables as jit OPERANDS


class StepMetrics(NamedTuple):
    loss: jnp.ndarray          # mean per-learner minibatch loss (active only)
    grad_norm: jnp.ndarray     # ||g_a|| (consensus gradient, active only)
    sigma_w_sq: jnp.ndarray    # weight variance across (active) learners
    staleness_mean: jnp.ndarray  # mean buffer age seen at gossip (adpsgd)
    staleness_max: jnp.ndarray   # max buffer age seen at gossip (adpsgd)
    # -- elastic/AdaScale statistics (zero-filled on the ssgd paths) -------
    n_active: jnp.ndarray = 0.0      # live learner count this tick
    grad_sq_mean: jnp.ndarray = 0.0  # mean_i ||g_i||^2 over active learners


def _select(mask, new, old):
    """Per-learner select: leaf[j] = new[j] if mask[j] else old[j]."""
    def _sel(a, b):
        m = mask.reshape((-1,) + (1,) * (a.ndim - 1))
        return jnp.where(m, a, b)
    return jax.tree_util.tree_map(_sel, new, old)


def _per_learner_grad_sq(grads):
    """(n,) f32: ||g_i||^2 per learner (the AdaScale gain statistic)."""
    return sum(jnp.sum(jnp.square(g.astype(jnp.float32)),
                       axis=tuple(range(1, g.ndim)))
               for g in jax.tree_util.tree_leaves(grads))


@dataclasses.dataclass
class ProbeHook:
    """One scheduled measurement pass (the probe seam, DESIGN §10).

    ``schedule`` is anything with ``due(step) -> bool`` (typically
    landscape.ProbeSchedule); ``fn(state, batch) -> result`` is the
    measurement (trainer.diagnostics, a landscape probe, ...);
    ``on_result(state, result) -> state`` optionally closes a control loop
    (e.g. AutoLR writing its multiplier into the optimizer state).
    """
    name: str
    schedule: Any
    fn: Callable
    on_result: Optional[Callable] = None


@dataclasses.dataclass
class MultiLearnerTrainer:
    loss_fn: Callable          # (params, batch) -> scalar, one learner's minibatch
    optimizer: Optimizer
    algo: AlgoConfig
    alpha_for_diag: float = 1.0   # alpha used in the alpha_e instrument
    hooks: list = dataclasses.field(default_factory=list)  # [ProbeHook]
    engine: str = "auto"       # auto | flat | pytree (DESIGN §11)
    kernel_backend: str = "auto"   # auto | pallas | ref (flat-engine dispatch)

    def __post_init__(self):
        # compile the topology into its GossipSchedule (DESIGN §12): the
        # per-round static-K partner/coef tables every mixing path — fused
        # kernel, einsum fallback, SPMD ppermute — derives from.  None for
        # 'solo' (identity mixing); unknown topologies raise here.
        self._schedule = gsched.make_schedule(
            self.algo.topology, self.algo.n_learners,
            rounds=self.algo.gossip_rounds)
        if (getattr(self.optimizer, "wants_mixed", False)
                and self.algo.gossip_order != "mix_then_descend"):
            raise ValueError("decentlam-style optimizers need the gossip "
                             "average: use gossip_order='mix_then_descend'")
        if (getattr(self.optimizer, "wants_mixed", False)
                and getattr(self.optimizer, "static_mixing_only", False)
                and self._schedule is not None
                and self._schedule.time_varying):
            raise ValueError(
                "this optimizer's correction assumes a STATIC mixing "
                f"matrix, but topology='{self.algo.topology}' compiles to a "
                "time-varying GossipSchedule — the exact DecentLaM drift "
                "diverges under switching matchings (see optim/decentlam.py)."
                " Use drift_scale=1-momentum, a static topology, or "
                "unsafe_switching=True to demonstrate the divergence")
        assert self.engine in ("auto", "flat", "pytree"), self.engine
        assert self.kernel_backend in ("auto", "pallas", "ref"), \
            self.kernel_backend
        layout_sensitive = getattr(self.optimizer, "layout_sensitive", False)
        if self.engine == "auto":
            # the flat fused engine is the default hot path for the
            # decentralized algorithms; SSGD/SSGD* keep the reference layout
            # (no gossip to fuse; SSGD* draws per-leaf weight noise), and so
            # does a layout-sensitive optimizer (lamb's layer-wise trust
            # ratio would silently collapse on the single flat leaf)
            self._flat = (self.algo.algo in ("dpsgd", "adpsgd")
                          and not layout_sensitive)
        else:
            if self.engine == "flat" and self.algo.algo == "ssgd_star":
                raise ValueError("ssgd_star draws per-leaf weight noise; "
                                 "use engine='pytree'")
            if self.engine == "flat" and layout_sensitive:
                raise ValueError(
                    "this optimizer's update depends on the per-leaf "
                    "structure (layout_sensitive=True, e.g. lamb's "
                    "layer-wise trust ratio) — the flat engine would "
                    "silently change its semantics; use engine='pytree'")
            self._flat = self.engine == "flat"
        # fused kernel path: plain (momentum-)SGD on ANY compiled gossip
        # schedule — every topology make_schedule covers dispatches the
        # batched Pallas/oracle kernel, multi-round schedules running their
        # leading rounds as mixing-only kernel passes (DESIGN §12).  SSGD
        # has no gossip to fuse (generic flat step); 'solo' has no schedule;
        # a wants_mixed optimizer (decentlam) needs the unfused update.
        f = getattr(self.optimizer, "fused", None)
        self._fused = None
        if (self._flat and f is not None
                and self.algo.algo in ("dpsgd", "adpsgd")
                and not getattr(self.optimizer, "wants_mixed", False)
                and self.algo.gossip_order == "mix_then_descend"
                and self._schedule is not None):
            self._fused = f
        self._meta: Optional[FlatMeta] = None   # set at init()
        # jit once per trainer instance (self is not hashable -> close over
        # it).  The step and the scan driver donate the state: the flat
        # buffers are updated in place, so a consumed state must not be
        # reused (tests pin this).
        self.train_step = jax.jit(self._train_step, donate_argnums=(0,))
        self._run_steps_jit = jax.jit(self._run_steps, donate_argnums=(0,))
        self.diagnostics = jax.jit(self._diagnostics)
        self.eval_loss = jax.jit(self._eval_loss)

    # -- engine helpers -------------------------------------------------------
    @property
    def is_flat(self) -> bool:
        return self._flat

    def _params_any(self, params):
        """Accept either layout: unflatten a flat buffer, pass trees through."""
        if self._flat and isinstance(params, jax.Array):
            return self._meta.unflatten(params)
        return params

    def params_tree(self, state_or_params):
        """The stacked parameter pytree view of a state (cheap slices)."""
        p = (state_or_params.params if isinstance(state_or_params, TrainState)
             else state_or_params)
        return self._params_any(p)

    def state_view(self, state: TrainState) -> TrainState:
        """Pytree-layout view of a (possibly flat) state.

        Parameters/buffer and any (n, T, 128) optimizer leaves (momentum)
        come back as stacked pytrees; scalar opt leaves (controller scale,
        schedule step) pass through.  Probe hooks receive this view so
        measurement code is engine-agnostic.
        """
        if not self._flat:
            return state
        meta = self._meta

        def leafview(x):
            if (isinstance(x, jax.Array) and x.ndim >= 2
                    and x.shape[-2:] == (meta.rows, LANE)):
                return meta.unflatten(x)
            return x

        return state._replace(
            params=meta.unflatten(state.params),
            buffer=(None if state.buffer is None
                    else meta.unflatten(state.buffer)),
            opt_state=jax.tree_util.tree_map(leafview, state.opt_state))

    def state_from_view(self, view: TrainState) -> TrainState:
        """Inverse of ``state_view``: re-flatten a pytree-layout state.

        Lets checkpoints stay layout-stable across engines: save
        ``state_view(state)``, restore it with the view as template, and
        feed the result back through here.  Any subtree matching the
        parameter structure (params, buffer, momentum leaves the view
        expanded) is flattened back into the (n, T, 128) store; everything
        else passes through.
        """
        if not self._flat:
            return view
        meta = self._meta

        def is_param_subtree(x):
            try:
                return jax.tree_util.tree_structure(x) == meta.treedef
            except Exception:
                return False

        def reflatten(x):
            return meta.flatten(x) if is_param_subtree(x) else x

        return view._replace(
            params=meta.flatten(view.params),
            buffer=(None if view.buffer is None
                    else meta.flatten(view.buffer)),
            opt_state=jax.tree_util.tree_map(reflatten, view.opt_state,
                                             is_leaf=is_param_subtree))

    def _loss_flat(self, w_flat, batch):
        return self.loss_fn(self._meta.unflatten(w_flat), batch)

    # -- init ---------------------------------------------------------------
    def init(self, key: jax.Array, params_single) -> TrainState:
        n = self.algo.n_learners
        stacked = jax.tree_util.tree_map(
            lambda p: jnp.broadcast_to(p[None], (n,) + p.shape).copy(), params_single)
        if self._flat:
            self._meta = flat_meta(params_single)
            stacked = self._meta.flatten(stacked)   # the ONE flatten
        opt_state = jax.vmap(self.optimizer.init)(stacked)
        buffer = age = clock = None
        if self.algo.algo == "adpsgd":
            buffer = jax.tree_util.tree_map(jnp.copy, stacked)
            age = jnp.zeros((n,), jnp.int32)
            clock = jnp.zeros((n,), jnp.int32)
        return TrainState(stacked, opt_state, jnp.zeros((), jnp.int32), key,
                          buffer=buffer, age=age, clock=clock)

    # -- optimizer call (decentlam-aware) -------------------------------------
    def _opt_update(self, grads, opt_state, params, mixed):
        if getattr(self.optimizer, "wants_mixed", False):
            return jax.vmap(self.optimizer.update)(grads, opt_state, params,
                                                   mixed)
        return jax.vmap(self.optimizer.update)(grads, opt_state, params)

    # -- flat-engine pieces ---------------------------------------------------
    def _fused_step(self, w, remote, grads, opt_state, partners, coefs,
                    active=None, buffer=None, nbr_fresh=None, publish=None,
                    weight_decay=None):
        """Dispatch the batched gossip+SGD kernel and thread the opt state.

        ``active`` (adpsgd): the kernel applies the straggler select to the
        weights and momentum in the same pass; the caller reverts the small
        non-flat opt leaves with ``_select_nonflat``.  ``buffer`` +
        ``nbr_fresh``/``publish`` switch on the AD-PSGD publish mode: the
        stale-remote select and the published-buffer rewrite also happen
        inside the kernel, so the tick makes one pass over the parameters.
        ``weight_decay`` overrides the optimizer's static recipe (the
        multi-round path passes 0 after folding the decay of the PRE-mix
        weights into the gradients — the kernel only sees the mixed w).
        Returns (w_new, opt_state[, buffer_new]).
        """
        from ..kernels import ops as kops
        f = self._fused
        n = w.shape[0]
        scale = jnp.broadcast_to(
            jnp.asarray(f.scale(opt_state), jnp.float32), (n,))
        act = (jnp.ones((n,), jnp.float32) if active is None
               else active.astype(jnp.float32))
        cols = [coefs, scale[:, None], act[:, None]]
        if buffer is not None:
            cols += [nbr_fresh.astype(jnp.float32)[:, None],
                     publish.astype(jnp.float32)[:, None]]
        coefs = jnp.concatenate(cols, axis=1)
        mu = f.read_mu(opt_state)
        wd = f.weight_decay if weight_decay is None else weight_decay
        out = kops.flat_gossip_update(
            w, remote, grads, mu, partners, coefs, lr=f.lr, beta=f.beta,
            weight_decay=wd, buffer=buffer,
            backend=self.kernel_backend)
        w_new, mu_new = out[0], out[1]
        opt_state = f.bump(opt_state)
        if mu_new is not None:
            opt_state = f.write_mu(opt_state, mu_new)
        if buffer is not None:
            return w_new, opt_state, out[2]
        return w_new, opt_state

    def _select_nonflat(self, mask, new, old):
        """Per-learner select skipping (T, 128) leaves the kernel already
        selected in-pass (the momentum buffer)."""
        meta = self._meta

        def _sel(a, b):
            if (isinstance(a, jax.Array) and a.ndim >= 2
                    and a.shape[-2:] == (meta.rows, LANE)):
                return a
            m = mask.reshape((-1,) + (1,) * (a.ndim - 1))
            return jnp.where(m, a, b)
        return jax.tree_util.tree_map(_sel, new, old)

    def _mix_sched(self, stacked, key, step):
        """Schedule-driven gossip for the UNFUSED paths (pytree engine and
        the flat engine's generic-optimizer fallback) — works on stacked
        pytrees and on the raw (n, T, 128) buffer alike.

        Random matchings keep the O(P) gather form (round 0 draws from the
        raw step key, so sync pairwise DPSGD stays bitwise-stable vs PR 1);
        deterministic schedules multiply by the compiled per-step matrix
        (the whole multi-round product in ONE einsum).
        """
        s = self._schedule
        if s is None:                     # solo: identity mixing
            return stacked
        if s.randomized:
            out = stacked
            for j in range(s.rounds_per_step):
                kj = key if j == 0 else jax.random.fold_in(key, j)
                out = mix_pair_gather(
                    out, pair_partners(kj, self.algo.n_learners))
            return out
        return mix_einsum(stacked, s.step_matrix(key, step))

    # -- elastic membership (DESIGN §15) --------------------------------------
    def membership_state(self, membership: Membership, *,
                         drop_round: bool = False) -> MemberState:
        """Device-side membership bundle for THIS trainer's topology:
        deterministic DPSGD schedules embed their ``reschedule`` tables,
        randomized matchings and AD-PSGD draw from the mask in-step."""
        topo_name = None
        if (self.algo.algo == "dpsgd" and self._schedule is not None
                and not self._schedule.randomized):
            topo_name = self.algo.topology
        return membership.member_state(
            topo_name, gossip_rounds=self.algo.gossip_rounds,
            drop_round=drop_round)

    def set_membership(self, state: TrainState, membership: Membership, *,
                       drop_round: bool = False) -> TrainState:
        """Swap the current membership into a state (a table/operand swap:
        same-shape swaps reuse the compiled step — never a retrace)."""
        if self.algo.algo not in ("dpsgd", "adpsgd"):
            raise ValueError("elastic membership rides the decentralized "
                             f"paths, not {self.algo.algo}")
        if getattr(self.optimizer, "wants_mixed", False):
            raise ValueError(
                "a mixing-matrix-corrected optimizer (decentlam) assumes a "
                "static fleet — its drift term diverges when membership "
                "changes the realized matrix; use plain (momentum-)SGD")
        assert membership.capacity == self.algo.n_learners, \
            (membership.capacity, self.algo.n_learners)
        return state._replace(
            members=self.membership_state(membership,
                                          drop_round=drop_round))

    def _member_rounds(self, mem: MemberState, key, step):
        """The elastic analogue of ``schedule.step_rounds``: per-round
        (partners (K, n), coefs (n, K+1)) tables for this step, built from
        the ``members`` OPERANDS (mask / reschedule tables) so a membership
        change never invalidates a jit cache through a stale closure.
        A dropped gossip round degrades every row to the identity."""
        n = self.algo.n_learners
        if mem.partners is None:     # randomized: only-active matching
            rps = (max(1, self.algo.gossip_rounds)
                   if self.algo.topology == "random_matching" else 1)
            out = []
            for j in range(rps):
                kj = key if j == 0 else jax.random.fold_in(key, j)
                partner = topo.masked_pair_partners(kj, mem.active,
                                                    drop=mem.drop_round)
                solo = partner == jnp.arange(n)
                self_c = jnp.where(solo, 1.0, 0.5).astype(jnp.float32)
                out.append((partner[None].astype(jnp.int32),
                            jnp.stack([self_c, 1.0 - self_c], axis=1)))
            return out
        period, K = mem.partners.shape[0], mem.partners.shape[1]
        # rps is derivable from the OPERAND shape (rps == period for every
        # deterministic schedule except one_peer_exp's one-round-per-step),
        # so a resize that changes the table shape retraces with the right
        # round structure by construction
        rps = 1 if self.algo.topology == "one_peer_exp" else period
        id_c = jnp.concatenate(
            [jnp.ones((n, 1), jnp.float32), jnp.zeros((n, K), jnp.float32)],
            axis=1)
        out = []
        for j in range(rps):
            if rps % period == 0:
                p, c = mem.partners[j % period], mem.coefs[j % period]
            else:                    # time-varying (one_peer_exp)
                ridx = (step * rps + j) % period
                p, c = mem.partners[ridx], mem.coefs[ridx]
            out.append((p, jnp.where(mem.drop_round, id_c, c)))
        return out

    def _mix_member_rounds(self, stacked, rounds, active):
        """Unfused elastic mixing: apply ``_member_rounds`` tables to a
        stacked tree / flat buffer.  Randomized matchings keep the O(P)
        pair-gather form (solo rows — including every inactive one —
        bitwise untouched); deterministic rounds realize the round matrix.
        Quarantined rows are zeroed before the einsum and restored after,
        so even a non-finite parked row cannot bleed through the 0-weight
        columns (0 * NaN is NaN in an einsum, not in a where)."""
        out = stacked
        randomized = self._schedule is not None and self._schedule.randomized
        for partners, coefs in rounds:
            if randomized:      # drop/solo already folded into the partners
                out = mix_pair_gather(out, partners[0])
                continue
            n = partners.shape[1]
            m = jnp.zeros((n, n), jnp.float32)
            m = m.at[jnp.arange(n), jnp.arange(n)].add(coefs[:, 0])
            for k in range(partners.shape[0]):
                m = m.at[jnp.arange(n), partners[k]].add(coefs[:, 1 + k])
            safe = _select(active, out,
                           jax.tree_util.tree_map(jnp.zeros_like, out))
            out = _select(active, mix_einsum(safe, m), out)
        return out

    # -- one training step ----------------------------------------------------
    def _train_step(self, state: TrainState, stacked_batch):
        """stacked_batch leaves: (n, B_local, ...)."""
        if self._flat:
            return self._train_step_flat(state, stacked_batch)
        return self._train_step_tree(state, stacked_batch)

    def _train_step_tree(self, state: TrainState, stacked_batch):
        algo = self.algo
        key = jax.random.fold_in(state.rng, state.step)
        k_mix, k_noise = jax.random.split(key)

        grad_fn = jax.value_and_grad(self.loss_fn)
        zero = jnp.zeros((), jnp.float32)
        stale_mean, stale_max = zero, zero
        buffer, age, clock = state.buffer, state.age, state.clock

        if algo.algo == "ssgd":
            w_a = learner_mean(state.params)
            losses, grads = jax.vmap(grad_fn, in_axes=(None, 0))(w_a, stacked_batch)
            g_mean = learner_mean(grads)
            # identical update on every learner keeps copies in sync
            g_stacked = jax.tree_util.tree_map(
                lambda g: jnp.broadcast_to(g[None], (algo.n_learners,) + g.shape),
                g_mean)
            updates, opt_state = self._opt_update(
                g_stacked, state.opt_state, state.params, state.params)
            new_params = apply_updates(state.params, updates)
            new_params = mean_broadcast(new_params)

        elif algo.algo == "ssgd_star":
            w_a = learner_mean(state.params)
            noisy = perturb_weights(
                k_noise,
                jax.tree_util.tree_map(
                    lambda p: jnp.broadcast_to(p[None],
                                               (algo.n_learners,) + p.shape), w_a),
                algo.noise_std)
            losses, grads = jax.vmap(grad_fn)(noisy, stacked_batch)
            g_mean = learner_mean(grads)
            g_stacked = jax.tree_util.tree_map(
                lambda g: jnp.broadcast_to(g[None], (algo.n_learners,) + g.shape),
                g_mean)
            updates, opt_state = self._opt_update(
                g_stacked, state.opt_state, state.params, state.params)
            new_params = apply_updates(state.params, updates)
            new_params = mean_broadcast(new_params)

        elif algo.algo == "dpsgd":
            # gradients at LOCAL weights (the whole point of the paper)
            losses, grads = jax.vmap(grad_fn)(state.params, stacked_batch)
            mem = state.members
            if mem is not None:       # elastic fleet (DESIGN §15)
                act = mem.active
                rounds = ([] if self._schedule is None
                          else self._member_rounds(mem, k_mix, state.step))
                if algo.gossip_order == "mix_then_descend":
                    mixed = self._mix_member_rounds(state.params, rounds, act)
                    updates, opt_new = self._opt_update(
                        grads, state.opt_state, state.params, mixed)
                    stepped = apply_updates(mixed, updates)
                else:
                    updates, opt_new = self._opt_update(
                        grads, state.opt_state, state.params, state.params)
                    stepped = self._mix_member_rounds(
                        apply_updates(state.params, updates), rounds, act)
                # dead learners' quarantined rows stay bitwise frozen
                new_params = _select(act, stepped, state.params)
                opt_state = _select(act, opt_new, state.opt_state)
            elif algo.gossip_order == "mix_then_descend":   # paper Eq. 2
                # _mix_sched keeps the gather form for random matchings
                # (O(P), and the reference AD-PSGD reduces to it at
                # staleness 0 — bitwise, asserted in tests) and the
                # compiled per-step matrix for everything else
                mixed = self._mix_sched(state.params, k_mix, state.step)
                updates, opt_state = self._opt_update(
                    grads, state.opt_state, state.params, mixed)
                new_params = apply_updates(mixed, updates)
            else:                                          # descend_then_mix
                updates, opt_state = self._opt_update(
                    grads, state.opt_state, state.params, state.params)
                new_params = self._mix_sched(
                    apply_updates(state.params, updates), k_mix, state.step)

        elif algo.algo == "adpsgd":
            # Async pairwise gossip, simulated one global tick at a time:
            #   active  — learners that finish a local step this tick (the
            #             injected straggler finishes every slow_factor ticks)
            #   remote  — what partners read: the last-published buffer, or
            #             the live weights once the staleness bound is hit
            n = algo.n_learners
            mem = state.members
            if mem is not None:       # elastic fleet (DESIGN §15)
                # a dead learner is a permanently-inactive straggler: never
                # active, never force-published, never matched
                active = member_active_mask(state.step, mem.active,
                                            mem.slow_every)
                fresh = (age >= algo.max_staleness) & mem.active
                stale_seen = jnp.where(fresh | ~mem.active, 0, age)
                partner = topo.masked_pair_partners(k_mix, mem.active,
                                                    drop=mem.drop_round)
            else:
                active = straggler_active_mask(state.step, n,
                                               algo.slow_learner,
                                               algo.slow_factor)
                fresh = age >= algo.max_staleness   # forced publish (tau)
                stale_seen = jnp.where(fresh, 0, age)
                partner = pair_partners(k_mix, n)
            remote = _select(fresh, state.params, buffer)
            stale_mean = jnp.mean(stale_seen.astype(jnp.float32))
            stale_max = jnp.max(stale_seen).astype(jnp.float32)

            losses, grads = jax.vmap(grad_fn)(state.params, stacked_batch)
            mixed = mix_pair_gather(state.params, partner, remote)
            updates, opt_state_new = self._opt_update(
                grads, state.opt_state, state.params, mixed)
            stepped = apply_updates(mixed, updates)

            # inactive learners are mid-step: weights and momentum unchanged
            new_params = _select(active, stepped, state.params)
            opt_state = _select(active, opt_state_new, state.opt_state)
            # publishing: completing a step publishes the new weights; a
            # forced-fresh learner re-publishes its (unchanged) in-progress
            # weights — both cases read off new_params
            buffer = _select(active | fresh, new_params, buffer)
            age = jnp.where(active | fresh, 0, age + 1)
            clock = clock + active.astype(jnp.int32)
        else:
            raise ValueError(algo.algo)

        mem = state.members
        gsq = _per_learner_grad_sq(grads)
        if mem is None:
            nact = jnp.float32(algo.n_learners)
            loss = jnp.mean(losses)
            g_mean = learner_mean(grads)
            gsq_mean = jnp.mean(gsq)
            sigma = learner_var(new_params)
        else:        # active-only statistics: evicted rows are bitwise-absent
            act = mem.active
            nact = jnp.maximum(jnp.sum(act), 1).astype(jnp.float32)
            loss = jnp.sum(jnp.where(act, losses, 0.0)) / nact
            g_mean = masked_learner_mean(grads, act)
            gsq_mean = jnp.sum(jnp.where(act, gsq, 0.0)) / nact
            sigma = masked_learner_var(new_params, act)
        metrics = StepMetrics(
            loss=loss,
            grad_norm=jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                                   for g in jax.tree_util.tree_leaves(
                                       g_mean))),
            sigma_w_sq=sigma,
            staleness_mean=stale_mean,
            staleness_max=stale_max,
            n_active=nact,
            grad_sq_mean=gsq_mean,
        )
        return TrainState(new_params, opt_state, state.step + 1, state.rng,
                          buffer=buffer, age=age, clock=clock,
                          members=state.members), metrics

    def _train_step_flat(self, state: TrainState, stacked_batch):
        """The flat-engine step: same algorithm semantics, (n, T, 128) state.

        Gradients are taken with respect to the flat buffer (chain rule
        through the unflatten views — their transpose is pad-and-add), so no
        parameter-sized flatten/concatenate is traced; the fused path then
        streams {w, remote, g, mu} through the batched Pallas kernel once.
        """
        algo = self.algo
        n = algo.n_learners
        key = jax.random.fold_in(state.rng, state.step)
        k_mix, _ = jax.random.split(key)

        grad_fn = jax.value_and_grad(self._loss_flat)
        zero = jnp.zeros((), jnp.float32)
        stale_mean, stale_max = zero, zero
        buffer, age, clock = state.buffer, state.age, state.clock
        w = state.params

        if algo.algo == "ssgd":
            w_a = jnp.mean(w, axis=0)
            losses, grads = jax.vmap(grad_fn, in_axes=(None, 0))(w_a,
                                                                 stacked_batch)
            g_mean = jnp.mean(grads, axis=0)
            g_stacked = jnp.broadcast_to(g_mean[None], w.shape)
            updates, opt_state = self._opt_update(g_stacked, state.opt_state,
                                                  w, w)
            new_params = apply_updates(w, updates)
            new_params = jnp.broadcast_to(jnp.mean(new_params, axis=0)[None],
                                          w.shape)

        elif algo.algo == "dpsgd":
            mem = state.members
            losses, grads = jax.vmap(grad_fn)(w, stacked_batch)
            if self._fused is not None:
                # the compiled schedule's per-step rounds: leading rounds
                # run as mixing-only kernel passes (multi-round schedules —
                # full-as-rounds, hierarchical, random_matching), the LAST
                # round fuses the momentum-SGD update into the same pass.
                # Elastic fleets swap in the membership-operand tables plus
                # the kernel's active column (dead rows stay bitwise put).
                from ..kernels import ops as kops
                act = None if mem is None else mem.active
                rounds = (self._schedule.step_rounds(k_mix, state.step)
                          if mem is None
                          else self._member_rounds(mem, k_mix, state.step))
                g_upd, wd = grads, None
                if len(rounds) > 1 and self._fused.weight_decay:
                    # weight decay regularizes the PRE-mix local weights
                    # (what the pytree reference does); once the leading
                    # rounds overwrite w the kernel would decay the mixed
                    # buffer instead — fold it into the gradients here and
                    # zero the kernel's own decay term (grads itself stays
                    # raw: the grad_norm metric reads it below)
                    g_upd = grads + self._fused.weight_decay * w
                    wd = 0.0
                for partners, coefs in rounds[:-1]:
                    w = kops.flat_gossip_mix(w, partners, coefs, active=act,
                                             backend=self.kernel_backend)
                partners, coefs = rounds[-1]
                new_params, opt_state_new = self._fused_step(
                    w, w, g_upd, state.opt_state, partners, coefs,
                    active=act, weight_decay=wd)
                opt_state = (opt_state_new if mem is None else
                             self._select_nonflat(act, opt_state_new,
                                                  state.opt_state))
            elif mem is not None:
                act = mem.active
                rounds = ([] if self._schedule is None
                          else self._member_rounds(mem, k_mix, state.step))
                if algo.gossip_order == "mix_then_descend":
                    mixed = self._mix_member_rounds(w, rounds, act)
                    updates, opt_state_new = self._opt_update(
                        grads, state.opt_state, w, mixed)
                    stepped = apply_updates(mixed, updates)
                else:                                   # descend_then_mix
                    updates, opt_state_new = self._opt_update(
                        grads, state.opt_state, w, w)
                    stepped = self._mix_member_rounds(
                        apply_updates(w, updates), rounds, act)
                new_params = jnp.where(act[:, None, None], stepped, w)
                opt_state = _select(act, opt_state_new, state.opt_state)
            elif algo.gossip_order == "mix_then_descend":
                mixed = self._mix_sched(w, k_mix, state.step)
                updates, opt_state = self._opt_update(grads, state.opt_state,
                                                      w, mixed)
                new_params = apply_updates(mixed, updates)
            else:                                       # descend_then_mix
                updates, opt_state = self._opt_update(grads, state.opt_state,
                                                      w, w)
                new_params = self._mix_sched(apply_updates(w, updates),
                                             k_mix, state.step)

        elif algo.algo == "adpsgd":
            mem = state.members
            if mem is None:
                active = straggler_active_mask(state.step, n,
                                               algo.slow_learner,
                                               algo.slow_factor)
                fresh = age >= algo.max_staleness
                stale_seen = jnp.where(fresh, 0, age)
            else:
                # elastic: liveness AND the per-learner tick divisor gate
                # the step; a dead learner can neither step nor be forced
                # to publish stale quarantined rows
                active = member_active_mask(state.step, mem.active,
                                            mem.slow_every)
                fresh = (age >= algo.max_staleness) & mem.active
                stale_seen = jnp.where(fresh | ~mem.active, 0, age)
            stale_mean = jnp.mean(stale_seen.astype(jnp.float32))
            stale_max = jnp.max(stale_seen).astype(jnp.float32)

            losses, grads = jax.vmap(grad_fn)(w, stacked_batch)
            if self._fused is not None:
                # the matching + solo-aware coefs come from the compiled
                # schedule — ONE source of truth with the DPSGD fused path
                # (the round-0 draw is the raw-key pair_partners, so the
                # bitwise sync==async(tau=0) contract is table-for-table).
                # Elastic fleets draw the only-active matching from the
                # membership mask instead.
                if mem is None:
                    (partners, coefs), = self._schedule.step_rounds(
                        k_mix, state.step)
                else:
                    (partners, coefs), = self._member_rounds(mem, k_mix,
                                                             state.step)
                partner = partners[0]
                # publish-mode kernel: stale-remote select, straggler select
                # AND the published-buffer rewrite all happen in the one
                # parameter pass; only the small non-flat opt leaves (scale,
                # schedule counters) still need the revert outside
                new_params, opt_state_new, buffer = self._fused_step(
                    w, w, grads, state.opt_state, partners, coefs,
                    active=active, buffer=buffer,
                    nbr_fresh=fresh[partner], publish=active | fresh)
                opt_state = self._select_nonflat(active, opt_state_new,
                                                 state.opt_state)
            else:
                if mem is None:
                    partner = pair_partners(k_mix, n)
                else:
                    partner = topo.masked_pair_partners(
                        k_mix, mem.active, drop=mem.drop_round)
                remote = jnp.where(fresh[:, None, None], w, buffer)
                mixed = mix_pair_gather(w, partner, remote)
                updates, opt_state_new = self._opt_update(
                    grads, state.opt_state, w, mixed)
                stepped = apply_updates(mixed, updates)
                new_params = jnp.where(active[:, None, None], stepped, w)
                opt_state = _select(active, opt_state_new, state.opt_state)
                buffer = jnp.where((active | fresh)[:, None, None],
                                   new_params, buffer)
            age = jnp.where(active | fresh, 0, age + 1)
            clock = clock + active.astype(jnp.int32)
        else:
            raise ValueError(f"flat engine does not run {algo.algo}; "
                             "use engine='pytree'")

        mem = state.members
        # centered two-pass variance on the single flat buffer: same value
        # as the per-leaf learner_var (pads contribute exactly 0) at about
        # half jnp.var's cost, and numerically safe at consensus (the
        # E[x^2]-E[x]^2 shortcut is NOT — it cancels catastrophically there)
        gsq = jnp.sum(jnp.square(grads), axis=(1, 2))
        if mem is None:
            nact = jnp.float32(n)
            loss = jnp.mean(losses)
            g_mean = jnp.mean(grads, axis=0)
            gsq_mean = jnp.mean(gsq)
            dev = new_params - jnp.mean(new_params, axis=0)
            sigma = jnp.sum(jnp.square(dev)) / n
        else:        # active-only statistics: quarantined rows are excluded
            act = mem.active
            nact = jnp.maximum(jnp.sum(act), 1).astype(jnp.float32)
            m3 = act[:, None, None]
            loss = jnp.sum(jnp.where(act, losses, 0.0)) / nact
            g_mean = jnp.sum(jnp.where(m3, grads, 0.0), axis=0) / nact
            gsq_mean = jnp.sum(jnp.where(act, gsq, 0.0)) / nact
            w_mean = jnp.sum(jnp.where(m3, new_params, 0.0), axis=0) / nact
            dev = jnp.where(m3, new_params - w_mean[None], 0.0)
            sigma = jnp.sum(jnp.square(dev)) / nact
        metrics = StepMetrics(
            loss=loss,
            grad_norm=jnp.sqrt(jnp.sum(jnp.square(g_mean))),
            sigma_w_sq=sigma,
            staleness_mean=stale_mean,
            staleness_max=stale_max,
            n_active=nact,
            grad_sq_mean=gsq_mean,
        )
        return TrainState(new_params, opt_state, state.step + 1, state.rng,
                          buffer=buffer, age=age, clock=clock,
                          members=state.members), metrics

    # -- multi-step scan driver (DESIGN §11) ----------------------------------
    def _run_steps(self, state: TrainState, stacked_batches):
        return jax.lax.scan(self._train_step, state, stacked_batches)

    def run_steps(self, state: TrainState, stacked_batches, k: int = None):
        """Run ``k`` fused steps under one lax.scan dispatch.

        stacked_batches leaves: (k, n, B_local, ...) — k prefetched
        minibatches per learner.  Returns (final state, StepMetrics with a
        leading (k,) axis).  The state argument is donated; between probe
        boundaries this is the preferred driver (no host round-trip per
        step).  ``k`` is optional validation sugar.
        """
        if k is not None:
            lead = jax.tree_util.tree_leaves(stacked_batches)[0].shape[0]
            if lead != k:
                raise ValueError(f"stacked_batches carry {lead} steps, "
                                 f"expected k={k}")
        return self._run_steps_jit(state, stacked_batches)

    # -- probe seam (replaces ad-hoc diag_every loops; DESIGN §10) ------------
    def add_probe(self, name: str, schedule, fn,
                  on_result: Optional[Callable] = None) -> None:
        """Register a scheduled probe.  ``schedule.due(step)`` gates it;
        ``fn(state, batch) -> result``; optional ``on_result(state, result)
        -> state`` feeds a controller back into the training state."""
        self.hooks.append(ProbeHook(name, schedule, fn, on_result))

    def probes_due(self, step: int) -> bool:
        """True if any registered probe fires at ``step`` (lets the host
        loop skip fetching a probe superbatch on quiet steps)."""
        return any(h.schedule.due(step) for h in self.hooks)

    def run_probes(self, state: TrainState, stacked_batch, step: int = None):
        """Run every due probe; returns (possibly updated state, {name: result}).

        Pass the same ``step`` you gated on with ``probes_due`` — a host
        loop counter can lag ``state.step`` (e.g. after a warm-up compile
        step) and silently firing on the wrong one would no-op the probes.
        Defaults to ``int(state.step)``.

        Probe fns receive the pytree ``state_view`` (engine-agnostic
        measurement code); ``on_result`` receives the REAL state so
        controllers write straight into the live (possibly flat) optimizer
        state.  Probes never donate the state — it outlives them.
        """
        step = int(state.step) if step is None else step
        results = {}
        for h in self.hooks:
            if not h.schedule.due(step):
                continue
            # view rebuilt per hook: a later hook's fn must observe state an
            # earlier hook's on_result already wrote (e.g. a controller
            # scale) — state_view is the identity on the pytree engine and
            # cheap slices on the flat one
            r = h.fn(self.state_view(state), stacked_batch)
            results[h.name] = r
            if h.on_result is not None:
                state = h.on_result(state, r)
        return state, results

    # -- diagnostics (paper Fig. 2b / Fig. 4) ---------------------------------
    def _diagnostics(self, state: TrainState, stacked_batch) -> DiagStats:
        return compute_diagnostics(self.loss_fn,
                                   self._params_any(state.params),
                                   stacked_batch,
                                   self.alpha_for_diag, age=state.age)

    # -- eval ----------------------------------------------------------------
    def _eval_loss(self, state: TrainState, batch):
        """Loss of the average model on a (B, ...) batch (heldout metric)."""
        if self._flat and isinstance(state.params, jax.Array):
            w_a = self._meta.unflatten(jnp.mean(state.params, axis=0))
            return self.loss_fn(w_a, batch)
        return self.loss_fn(learner_mean(state.params), batch)
