"""MultiLearnerTrainer — research driver for SSGD / SSGD* / DPSGD / AD-PSGD.

Semantics (paper Sec. 2 + Lian et al. 2018 for the async variant):
  SSGD   : g_j = grad L^{mu_j}(w_a);          w_a <- w_a + opt(mean_j g_j)
  SSGD*  : g_j = grad L^{mu_j}(w_a + delta_j) with delta_j ~ N(0, sigma0^2 I)
  DPSGD  : g_j = grad L^{mu_j}(w_j);          w_j <- mix(w)_j + opt_j(g_j)
  AD-PSGD: like DPSGD with pairwise gossip, but the partner's contribution is
           its last *published* weights (stale by up to ``max_staleness``
           ticks), and an injected straggler only completes a step every
           ``slow_factor`` ticks.  Modeled with explicit per-learner
           buffer/age/clock state so the step stays one jitted function.

State always carries *stacked* params (leading learner axis n) so the
algorithms are interchangeable and all diagnostics apply uniformly.  For SSGD
the stacked copies stay bitwise identical (asserted in tests).

This module is the CPU-scale research path (vmap over learners on one
device).  The production pjit/shard_map path lives in repro/launch/train.py
and reuses the same pure update functions.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from . import topology as topo
from .diagnostics import DiagStats, compute_diagnostics
from .dpsgd import (AlgoConfig, mean_broadcast, mix_einsum, mix_pair_gather,
                    pair_partners, perturb_weights, straggler_active_mask)
from .util import learner_mean, learner_var
from ..optim import Optimizer, apply_updates


class TrainState(NamedTuple):
    params: Any           # stacked: leaves (n, ...)
    opt_state: Any        # stacked per-learner
    step: jnp.ndarray
    rng: jax.Array
    # -- adpsgd only (None otherwise) --------------------------------------
    buffer: Any = None    # last-published weights, stacked like params
    age: Any = None       # (n,) int32 ticks since each learner published
    clock: Any = None     # (n,) int32 completed local steps per learner


class StepMetrics(NamedTuple):
    loss: jnp.ndarray          # mean per-learner minibatch loss
    grad_norm: jnp.ndarray     # ||g_a||
    sigma_w_sq: jnp.ndarray    # weight variance across learners
    staleness_mean: jnp.ndarray  # mean buffer age seen at gossip (adpsgd)
    staleness_max: jnp.ndarray   # max buffer age seen at gossip (adpsgd)


def _select(mask, new, old):
    """Per-learner select: leaf[j] = new[j] if mask[j] else old[j]."""
    def _sel(a, b):
        m = mask.reshape((-1,) + (1,) * (a.ndim - 1))
        return jnp.where(m, a, b)
    return jax.tree_util.tree_map(_sel, new, old)


@dataclasses.dataclass
class ProbeHook:
    """One scheduled measurement pass (the probe seam, DESIGN §10).

    ``schedule`` is anything with ``due(step) -> bool`` (typically
    landscape.ProbeSchedule); ``fn(state, batch) -> result`` is the
    measurement (trainer.diagnostics, a landscape probe, ...);
    ``on_result(state, result) -> state`` optionally closes a control loop
    (e.g. AutoLR writing its multiplier into the optimizer state).
    """
    name: str
    schedule: Any
    fn: Callable
    on_result: Optional[Callable] = None


@dataclasses.dataclass
class MultiLearnerTrainer:
    loss_fn: Callable          # (params, batch) -> scalar, one learner's minibatch
    optimizer: Optimizer
    algo: AlgoConfig
    alpha_for_diag: float = 1.0   # alpha used in the alpha_e instrument
    hooks: list = dataclasses.field(default_factory=list)  # [ProbeHook]

    def __post_init__(self):
        self._mix_fn = topo.make_mixing_fn(self.algo.topology, self.algo.n_learners)
        if (getattr(self.optimizer, "wants_mixed", False)
                and self.algo.gossip_order != "mix_then_descend"):
            raise ValueError("decentlam-style optimizers need the gossip "
                             "average: use gossip_order='mix_then_descend'")
        # jit once per trainer instance (self is not hashable -> close over it)
        self.train_step = jax.jit(self._train_step)
        self.diagnostics = jax.jit(self._diagnostics)
        self.eval_loss = jax.jit(self._eval_loss)

    # -- init ---------------------------------------------------------------
    def init(self, key: jax.Array, params_single) -> TrainState:
        n = self.algo.n_learners
        stacked = jax.tree_util.tree_map(
            lambda p: jnp.broadcast_to(p[None], (n,) + p.shape).copy(), params_single)
        opt_state = jax.vmap(self.optimizer.init)(stacked)
        buffer = age = clock = None
        if self.algo.algo == "adpsgd":
            buffer = jax.tree_util.tree_map(jnp.copy, stacked)
            age = jnp.zeros((n,), jnp.int32)
            clock = jnp.zeros((n,), jnp.int32)
        return TrainState(stacked, opt_state, jnp.zeros((), jnp.int32), key,
                          buffer=buffer, age=age, clock=clock)

    # -- optimizer call (decentlam-aware) -------------------------------------
    def _opt_update(self, grads, opt_state, params, mixed):
        if getattr(self.optimizer, "wants_mixed", False):
            return jax.vmap(self.optimizer.update)(grads, opt_state, params,
                                                   mixed)
        return jax.vmap(self.optimizer.update)(grads, opt_state, params)

    # -- one training step ----------------------------------------------------
    def _train_step(self, state: TrainState, stacked_batch):
        """stacked_batch leaves: (n, B_local, ...)."""
        algo = self.algo
        key = jax.random.fold_in(state.rng, state.step)
        k_mix, k_noise = jax.random.split(key)

        grad_fn = jax.value_and_grad(self.loss_fn)
        zero = jnp.zeros((), jnp.float32)
        stale_mean, stale_max = zero, zero
        buffer, age, clock = state.buffer, state.age, state.clock

        if algo.algo == "ssgd":
            w_a = learner_mean(state.params)
            losses, grads = jax.vmap(grad_fn, in_axes=(None, 0))(w_a, stacked_batch)
            g_mean = learner_mean(grads)
            # identical update on every learner keeps copies in sync
            g_stacked = jax.tree_util.tree_map(
                lambda g: jnp.broadcast_to(g[None], (algo.n_learners,) + g.shape),
                g_mean)
            updates, opt_state = self._opt_update(
                g_stacked, state.opt_state, state.params, state.params)
            new_params = apply_updates(state.params, updates)
            new_params = mean_broadcast(new_params)

        elif algo.algo == "ssgd_star":
            w_a = learner_mean(state.params)
            noisy = perturb_weights(
                k_noise,
                jax.tree_util.tree_map(
                    lambda p: jnp.broadcast_to(p[None],
                                               (algo.n_learners,) + p.shape), w_a),
                algo.noise_std)
            losses, grads = jax.vmap(grad_fn)(noisy, stacked_batch)
            g_mean = learner_mean(grads)
            g_stacked = jax.tree_util.tree_map(
                lambda g: jnp.broadcast_to(g[None], (algo.n_learners,) + g.shape),
                g_mean)
            updates, opt_state = self._opt_update(
                g_stacked, state.opt_state, state.params, state.params)
            new_params = apply_updates(state.params, updates)
            new_params = mean_broadcast(new_params)

        elif algo.algo == "dpsgd":
            # gradients at LOCAL weights (the whole point of the paper)
            losses, grads = jax.vmap(grad_fn)(state.params, stacked_batch)
            if algo.gossip_order == "mix_then_descend":   # paper Eq. 2
                if algo.topology == "random_pair":
                    # gather form of the random matching: O(P) instead of an
                    # n x n einsum, and the reference AD-PSGD reduces to at
                    # staleness 0 (bitwise — asserted in tests)
                    mixed = mix_pair_gather(state.params,
                                            pair_partners(k_mix, algo.n_learners))
                else:
                    mixed = mix_einsum(state.params, self._mix_fn(k_mix))
                updates, opt_state = self._opt_update(
                    grads, state.opt_state, state.params, mixed)
                new_params = apply_updates(mixed, updates)
            else:                                          # descend_then_mix
                updates, opt_state = self._opt_update(
                    grads, state.opt_state, state.params, state.params)
                new_params = mix_einsum(apply_updates(state.params, updates),
                                        self._mix_fn(k_mix))

        elif algo.algo == "adpsgd":
            # Async pairwise gossip, simulated one global tick at a time:
            #   active  — learners that finish a local step this tick (the
            #             injected straggler finishes every slow_factor ticks)
            #   remote  — what partners read: the last-published buffer, or
            #             the live weights once the staleness bound is hit
            n = algo.n_learners
            active = straggler_active_mask(state.step, n, algo.slow_learner,
                                           algo.slow_factor)
            fresh = age >= algo.max_staleness      # forced publish (bound tau)
            remote = _select(fresh, state.params, buffer)
            stale_seen = jnp.where(fresh, 0, age)
            stale_mean = jnp.mean(stale_seen.astype(jnp.float32))
            stale_max = jnp.max(stale_seen).astype(jnp.float32)

            losses, grads = jax.vmap(grad_fn)(state.params, stacked_batch)
            partner = pair_partners(k_mix, n)
            mixed = mix_pair_gather(state.params, partner, remote)
            updates, opt_state_new = self._opt_update(
                grads, state.opt_state, state.params, mixed)
            stepped = apply_updates(mixed, updates)

            # inactive learners are mid-step: weights and momentum unchanged
            new_params = _select(active, stepped, state.params)
            opt_state = _select(active, opt_state_new, state.opt_state)
            # publishing: completing a step publishes the new weights; a
            # forced-fresh learner re-publishes its (unchanged) in-progress
            # weights — both cases read off new_params
            buffer = _select(active | fresh, new_params, buffer)
            age = jnp.where(active | fresh, 0, age + 1)
            clock = clock + active.astype(jnp.int32)
        else:
            raise ValueError(algo.algo)

        metrics = StepMetrics(
            loss=jnp.mean(losses),
            grad_norm=jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                                   for g in jax.tree_util.tree_leaves(
                                       learner_mean(grads)))),
            sigma_w_sq=learner_var(new_params),
            staleness_mean=stale_mean,
            staleness_max=stale_max,
        )
        return TrainState(new_params, opt_state, state.step + 1, state.rng,
                          buffer=buffer, age=age, clock=clock), metrics

    # -- probe seam (replaces ad-hoc diag_every loops; DESIGN §10) ------------
    def add_probe(self, name: str, schedule, fn,
                  on_result: Optional[Callable] = None) -> None:
        """Register a scheduled probe.  ``schedule.due(step)`` gates it;
        ``fn(state, batch) -> result``; optional ``on_result(state, result)
        -> state`` feeds a controller back into the training state."""
        self.hooks.append(ProbeHook(name, schedule, fn, on_result))

    def probes_due(self, step: int) -> bool:
        """True if any registered probe fires at ``step`` (lets the host
        loop skip fetching a probe superbatch on quiet steps)."""
        return any(h.schedule.due(step) for h in self.hooks)

    def run_probes(self, state: TrainState, stacked_batch, step: int = None):
        """Run every due probe; returns (possibly updated state, {name: result}).

        Pass the same ``step`` you gated on with ``probes_due`` — a host
        loop counter can lag ``state.step`` (e.g. after a warm-up compile
        step) and silently firing on the wrong one would no-op the probes.
        Defaults to ``int(state.step)``.
        """
        step = int(state.step) if step is None else step
        results = {}
        for h in self.hooks:
            if not h.schedule.due(step):
                continue
            r = h.fn(state, stacked_batch)
            results[h.name] = r
            if h.on_result is not None:
                state = h.on_result(state, r)
        return state, results

    # -- diagnostics (paper Fig. 2b / Fig. 4) ---------------------------------
    def _diagnostics(self, state: TrainState, stacked_batch) -> DiagStats:
        return compute_diagnostics(self.loss_fn, state.params, stacked_batch,
                                   self.alpha_for_diag, age=state.age)

    # -- eval ----------------------------------------------------------------
    def _eval_loss(self, state: TrainState, batch):
        """Loss of the average model on a (B, ...) batch (heldout metric)."""
        return self.loss_fn(learner_mean(state.params), batch)
