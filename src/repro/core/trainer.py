"""MultiLearnerTrainer — research-scale driver for SSGD / SSGD* / DPSGD.

Semantics (paper Sec. 2):
  SSGD   : g_j = grad L^{mu_j}(w_a);          w_a <- w_a + opt(mean_j g_j)
  SSGD*  : g_j = grad L^{mu_j}(w_a + delta_j) with delta_j ~ N(0, sigma0^2 I)
  DPSGD  : g_j = grad L^{mu_j}(w_j);          w_j <- mix(w)_j + opt_j(g_j)

State always carries *stacked* params (leading learner axis n) so the three
algorithms are interchangeable and all diagnostics apply uniformly.  For SSGD
the stacked copies stay bitwise identical (asserted in tests).

This module is the CPU-scale research path (vmap over learners on one
device).  The production pjit/shard_map path lives in repro/launch/train.py
and reuses the same pure update functions.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from . import topology as topo
from .diagnostics import DiagStats, compute_diagnostics
from .dpsgd import AlgoConfig, mean_broadcast, mix_einsum, perturb_weights
from .util import learner_mean, learner_var
from ..optim import Optimizer, apply_updates


class TrainState(NamedTuple):
    params: Any           # stacked: leaves (n, ...)
    opt_state: Any        # stacked per-learner
    step: jnp.ndarray
    rng: jax.Array


class StepMetrics(NamedTuple):
    loss: jnp.ndarray          # mean per-learner minibatch loss
    grad_norm: jnp.ndarray     # ||g_a||
    sigma_w_sq: jnp.ndarray    # weight variance across learners


@dataclasses.dataclass
class MultiLearnerTrainer:
    loss_fn: Callable          # (params, batch) -> scalar, one learner's minibatch
    optimizer: Optimizer
    algo: AlgoConfig
    alpha_for_diag: float = 1.0   # alpha used in the alpha_e instrument

    def __post_init__(self):
        self._mix_fn = topo.make_mixing_fn(self.algo.topology, self.algo.n_learners)
        # jit once per trainer instance (self is not hashable -> close over it)
        self.train_step = jax.jit(self._train_step)
        self.diagnostics = jax.jit(self._diagnostics)
        self.eval_loss = jax.jit(self._eval_loss)

    # -- init ---------------------------------------------------------------
    def init(self, key: jax.Array, params_single) -> TrainState:
        n = self.algo.n_learners
        stacked = jax.tree_util.tree_map(
            lambda p: jnp.broadcast_to(p[None], (n,) + p.shape).copy(), params_single)
        opt_state = jax.vmap(self.optimizer.init)(stacked)
        return TrainState(stacked, opt_state, jnp.zeros((), jnp.int32), key)

    # -- one training step ----------------------------------------------------
    def _train_step(self, state: TrainState, stacked_batch):
        """stacked_batch leaves: (n, B_local, ...)."""
        algo = self.algo
        key = jax.random.fold_in(state.rng, state.step)
        k_mix, k_noise = jax.random.split(key)

        grad_fn = jax.value_and_grad(self.loss_fn)

        if algo.algo == "ssgd":
            w_a = learner_mean(state.params)
            losses, grads = jax.vmap(grad_fn, in_axes=(None, 0))(w_a, stacked_batch)
            g_mean = learner_mean(grads)
            # identical update on every learner keeps copies in sync
            g_stacked = jax.tree_util.tree_map(
                lambda g: jnp.broadcast_to(g[None], (algo.n_learners,) + g.shape),
                g_mean)
            updates, opt_state = jax.vmap(self.optimizer.update)(
                g_stacked, state.opt_state, state.params)
            new_params = apply_updates(state.params, updates)
            new_params = mean_broadcast(new_params)

        elif algo.algo == "ssgd_star":
            w_a = learner_mean(state.params)
            noisy = perturb_weights(
                k_noise,
                jax.tree_util.tree_map(
                    lambda p: jnp.broadcast_to(p[None],
                                               (algo.n_learners,) + p.shape), w_a),
                algo.noise_std)
            losses, grads = jax.vmap(grad_fn)(noisy, stacked_batch)
            g_mean = learner_mean(grads)
            g_stacked = jax.tree_util.tree_map(
                lambda g: jnp.broadcast_to(g[None], (algo.n_learners,) + g.shape),
                g_mean)
            updates, opt_state = jax.vmap(self.optimizer.update)(
                g_stacked, state.opt_state, state.params)
            new_params = apply_updates(state.params, updates)
            new_params = mean_broadcast(new_params)

        elif algo.algo == "dpsgd":
            # gradients at LOCAL weights (the whole point of the paper)
            losses, grads = jax.vmap(grad_fn)(state.params, stacked_batch)
            updates, opt_state = jax.vmap(self.optimizer.update)(
                grads, state.opt_state, state.params)
            m = self._mix_fn(k_mix)
            if algo.gossip_order == "mix_then_descend":   # paper Eq. 2
                mixed = mix_einsum(state.params, m)
                new_params = apply_updates(mixed, updates)
            else:                                          # descend_then_mix
                new_params = mix_einsum(apply_updates(state.params, updates), m)
        else:
            raise ValueError(algo.algo)

        metrics = StepMetrics(
            loss=jnp.mean(losses),
            grad_norm=jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                                   for g in jax.tree_util.tree_leaves(
                                       learner_mean(grads)))),
            sigma_w_sq=learner_var(new_params),
        )
        return TrainState(new_params, opt_state, state.step + 1, state.rng), metrics

    # -- diagnostics (paper Fig. 2b / Fig. 4) ---------------------------------
    def _diagnostics(self, state: TrainState, stacked_batch) -> DiagStats:
        return compute_diagnostics(self.loss_fn, state.params, stacked_batch,
                                   self.alpha_for_diag)

    # -- eval ----------------------------------------------------------------
    def _eval_loss(self, state: TrainState, batch):
        """Loss of the average model on a (B, ...) batch (heldout metric)."""
        return self.loss_fn(learner_mean(state.params), batch)
