"""The paper's analysis instruments (Sec. 2 + Appendix B).

Definitions (all on the flattened parameter space):

  w_a        = (1/n) sum_j w_j                      average weight
  g          = grad L(w_a)  over the SUPERBATCH mu  "true" direction
  g_j        = grad L^{mu_j}(w_j or w_a)            per-learner gradient
  g_a        = (1/n) sum_j g_j
  alpha_e    = alpha * (g_a . g) / ||g||^2          effective learning rate (Eq. 4)
  eta_perp   = -alpha g_a + alpha_e g               orthogonal noise
  Delta      = ||eta_perp||^2                       noise strength
  Delta_S    = alpha^2 sum_j ||g_j(w_a) - g0||^2 / (n(n-1))   SSGD noise
               (App. B: alpha^2 sigma_mb^2/n with the unbiased sample
               estimate of the minibatch-gradient variance sigma_mb^2)
  Delta2     = alpha^2 ||(1/n) sum_j [grad L^{mu_j}(w_j) - grad L^{mu_j}(w_a)]||^2
  sigma_w^2  = Tr(C) = sum_l (1/n) sum_j (w_jl - w_al)^2   weight variance

These are *optional* (diag_every) because they require an extra
forward/backward at w_a over the superbatch.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from .util import (learner_mean, learner_var, tree_dot, tree_norm_sq,
                   tree_scale, tree_sub)


class DiagStats(NamedTuple):
    alpha_e: jnp.ndarray        # effective learning rate (Eq. 4)
    sigma_w_sq: jnp.ndarray     # weight variance Tr(C)
    delta_total: jnp.ndarray    # ||eta_perp||^2
    delta_s: jnp.ndarray        # SSGD (minibatch) noise component
    delta_2: jnp.ndarray        # landscape-dependent DPSGD component (Eq. 5)
    grad_norm: jnp.ndarray      # ||g|| at w_a over superbatch
    ga_norm: jnp.ndarray        # ||g_a||
    loss_at_mean: jnp.ndarray
    consensus_dist: jnp.ndarray  # sqrt((1/n) sum_j ||w_j - w_a||^2)
    staleness_mean: jnp.ndarray  # mean per-learner buffer age (adpsgd; else 0)
    staleness_max: jnp.ndarray   # max per-learner buffer age (adpsgd; else 0)


def compute_diagnostics(loss_fn: Callable, stacked_params, stacked_batch,
                        alpha, age=None) -> DiagStats:
    """loss_fn(params, batch) -> scalar loss for ONE learner's minibatch.

    stacked_params: leaves (n, ...); stacked_batch: leaves (n, B, ...).
    ``age`` is AD-PSGD's (n,) per-learner buffer age (ticks since each
    learner last published); None for the synchronous algorithms.
    """
    w_a = learner_mean(stacked_params)

    # g_j at local weights w_j (DPSGD gradients)
    g_local = jax.vmap(jax.grad(loss_fn))(stacked_params, stacked_batch)
    g_a = learner_mean(g_local)

    # g_j at the mean weights (SSGD gradients) and superbatch gradient g0=g
    loss_mean_vals, g_at_mean = jax.vmap(
        jax.value_and_grad(loss_fn), in_axes=(None, 0))(w_a, stacked_batch)
    g0 = learner_mean(g_at_mean)          # superbatch gradient at w_a
    g = g0                                 # direction of the full-batch gradient

    g_norm_sq = tree_norm_sq(g)
    safe = jnp.maximum(g_norm_sq, 1e-30)
    alpha = jnp.asarray(alpha, jnp.float32)

    alpha_e = alpha * tree_dot(g_a, g) / safe

    # eta_perp = -alpha g_a + alpha_e g ; Delta = ||eta_perp||^2
    eta = tree_sub(tree_scale(alpha_e, g), tree_scale(alpha, g_a))
    delta_total = tree_norm_sq(eta)

    # Delta_S (App. B): the SSGD minibatch-noise strength
    #     Delta_S = alpha^2 E||g_bar - g_true||^2 = alpha^2 sigma_mb^2 / n
    # where g_bar = (1/n) sum_j g_j(w_a) is the superbatch gradient and
    # sigma_mb^2 = E||g_j(w_a) - g_true||^2 the per-minibatch variance.
    # The closed form alpha^2(||g0||^2 - (g0.g)^2/||g||^2) is 0 here because
    # g == g0 by construction (superbatch == union of minibatches), so we
    # estimate sigma_mb^2 from the sample instead.  Because g0 is the mean
    # OF the g_j, the naive mean_j ||g_j - g0||^2 underestimates sigma_mb^2
    # by (n-1)/n (sample-variance bias); the unbiased estimator is
    # sum_j ||g_j - g0||^2 / (n-1), giving
    #     Delta_S = alpha^2 sum_j ||g_j(w_a) - g0||^2 / (n (n-1)).
    dev = jax.tree_util.tree_map(lambda gj, gm: gj - gm[None], g_at_mean, g0)
    per = jax.vmap(tree_norm_sq)(dev)
    n = per.shape[0]
    delta_s = alpha ** 2 * jnp.sum(per) / (n * max(n - 1, 1))

    # Delta^(2): gradients moved by the weight spread (Eq. 5 numerator)
    diff = tree_sub(g_a, learner_mean(g_at_mean))
    delta_2 = alpha ** 2 * tree_norm_sq(diff)

    sigma_w_sq = learner_var(stacked_params)
    if age is None:
        stale_mean = stale_max = jnp.zeros((), jnp.float32)
    else:
        stale_mean = jnp.mean(age.astype(jnp.float32))
        stale_max = jnp.max(age).astype(jnp.float32)

    return DiagStats(
        alpha_e=alpha_e,
        sigma_w_sq=sigma_w_sq,
        delta_total=delta_total,
        delta_s=delta_s,
        delta_2=delta_2,
        grad_norm=jnp.sqrt(g_norm_sq),
        ga_norm=jnp.sqrt(tree_norm_sq(g_a)),
        loss_at_mean=jnp.mean(loss_mean_vals),
        # sigma_w_sq IS the squared consensus distance (1/n) sum ||w_j - w_a||^2
        consensus_dist=jnp.sqrt(sigma_w_sq),
        staleness_mean=stale_mean,
        staleness_max=stale_max,
    )
