"""Theorem-1 instrument: randomized smoothing of the loss landscape.

Theorem 1 says DPSGD implicitly optimizes L~(w) = E_{delta~N(0, sigma_w^2 I)}
[L(w + delta)], and (via Nesterov & Spokoiny Lemma 2) if L is G-Lipschitz then
L~ is (2G/sigma_w)-smooth.  We provide:

  * smoothed_loss: Monte-Carlo estimate of L~
  * estimate_smoothness: empirical gradient-Lipschitz constant
        l_s ~= max ||grad f(x) - grad f(y)|| / ||x - y||
    over random probe pairs, for both L and L~ — the test asserts the
    smoothed landscape has a smaller constant (the paper's core claim).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from .util import tree_add, tree_gaussian_like, tree_norm_sq, tree_sub


def smoothed_loss(loss_fn: Callable, params, batch, key, sigma: float,
                  n_samples: int = 8):
    """Monte-Carlo L~(w) = E_delta L(w + delta)."""
    keys = jax.random.split(key, n_samples)

    def one(k):
        noisy = tree_add(params, tree_gaussian_like(k, params, sigma))
        return loss_fn(noisy, batch)
    return jnp.mean(jax.vmap(one)(keys))


def smoothed_grad(loss_fn: Callable, params, batch, key, sigma: float,
                  n_samples: int = 8):
    return jax.grad(
        lambda p: smoothed_loss(loss_fn, p, batch, key, sigma, n_samples))(params)


def estimate_smoothness(loss_fn: Callable, params, batch, key,
                        sigma: float = 0.0, n_pairs: int = 8,
                        probe_radius: float = 0.05, n_mc: int = 8) -> jnp.ndarray:
    """Empirical l_s = max_i ||g(x_i) - g(y_i)|| / ||x_i - y_i||.

    sigma == 0 probes the raw landscape L; sigma > 0 probes the smoothed L~.
    """
    def gradf(p, k):
        if sigma == 0.0:
            return jax.grad(lambda q: loss_fn(q, batch))(p)
        return smoothed_grad(loss_fn, p, batch, k, sigma, n_mc)

    keys = jax.random.split(key, n_pairs * 3).reshape(n_pairs, 3, -1)

    def one(ks):
        k1, k2, k3 = ks[0], ks[1], ks[2]
        x = tree_add(params, tree_gaussian_like(k1, params, probe_radius))
        y = tree_add(x, tree_gaussian_like(k2, params, probe_radius))
        gx = gradf(x, k3)
        gy = gradf(y, k3)
        num = jnp.sqrt(tree_norm_sq(tree_sub(gx, gy)))
        den = jnp.sqrt(tree_norm_sq(tree_sub(x, y)))
        return num / jnp.maximum(den, 1e-12)

    # one vmapped probe batch instead of a Python loop of n_pairs traces
    return jnp.max(jax.vmap(one)(keys))
