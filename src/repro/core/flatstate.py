"""Flat-state parameter store: the (T, 128) layout as a *persistent* buffer.

The Pallas gossip kernels (DESIGN §7) and the Lanczos probe (§10) both live
on a lane-aligned (T, 128) f32 view of the parameter pytree.  Until PR 3 that
view was rebuilt per call — a full concatenate + dtype round-trip over every
leaf, i.e. one extra read+write of the whole model per step, which is more
HBM traffic than the fused kernel saves.

This module makes the flat view the *source of truth* instead:

  * ``FlatMeta`` captures the pytree structure ONCE (treedef, per-leaf
    shapes/dtypes, sizes, precomputed offsets, padded row count).  It is
    static, hashable metadata — safe to close over in a jitted step and
    cached per structure (``flat_meta``).
  * ``FlatMeta.flatten`` builds the (..., T, 128) f32 buffer (arbitrary
    leading axes, e.g. the learner axis n).  The trainer calls it exactly
    once, at init.
  * ``FlatMeta.unflatten`` reconstitutes per-leaf views with precomputed
    static slices — no concatenate, no offset rebuilding.  It carries a
    custom VJP that scatters the cotangent straight back into ONE flat
    buffer, so taking gradients *with respect to the flat buffer* keeps the
    whole train step free of parameter-sized concatenates (asserted by
    ``max_concat_elems`` in tests).

Padding: T is rounded up to a multiple of ``ROW_ALIGN`` (f32 sublane tile)
so any divisor-of-T block size is legal for the kernels.  The pad region is
written as zeros at flatten time and never escapes: unflatten drops it, and
gradients through unflatten are identically zero there.
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache, partial
from typing import Any, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["LANE", "ROW_ALIGN", "FlatMeta", "flat_meta", "max_concat_elems"]

LANE = 128
ROW_ALIGN = 8           # f32 sublane tile: keeps every divisor-of-T block legal


@dataclasses.dataclass(frozen=True)
class FlatMeta:
    """Static description of a pytree's flat (T, 128) layout."""
    treedef: Any
    shapes: Tuple[Tuple[int, ...], ...]
    dtypes: Tuple[Any, ...]            # per-leaf np.dtypes, preserved on unflatten
    sizes: Tuple[int, ...]
    offsets: Tuple[int, ...]           # precomputed once — never per call
    n_elem: int                        # real (unpadded) element count
    rows: int                          # T: padded row count, multiple of ROW_ALIGN

    @classmethod
    def for_tree(cls, tree) -> "FlatMeta":
        """Build metadata from a pytree (concrete or abstract leaves);
        same cached instance as ``flat_meta``."""
        return flat_meta(tree)

    # -- layout --------------------------------------------------------------
    @property
    def padded(self) -> int:
        return self.rows * LANE

    def leading(self, tree_or_flat, *, flat: bool) -> Tuple[int, ...]:
        if flat:
            return tuple(tree_or_flat.shape[:-2])
        leaves = jax.tree_util.tree_leaves(tree_or_flat,
                                           is_leaf=lambda x: x is None)
        for leaf, shape in zip(leaves, self.shapes):
            if leaf is None:          # align with metadata past None leaves
                continue
            nd = len(leaf.shape) - len(shape)
            return tuple(leaf.shape[:nd])
        return ()

    # -- conversions ---------------------------------------------------------
    def flatten(self, tree, dtype=jnp.float32) -> jnp.ndarray:
        """Pytree (leaves ``lead + shape``) -> (lead + (T, 128)) buffer.

        The ONE place a parameter-sized concatenate is allowed — called at
        trainer init (and in the thin ``flatten_for_kernel`` shim), never
        inside the hot step.  ``dtype`` defaults to the f32 compute layout;
        the flat gossip collectives pass the params' own wire dtype so a
        bf16 model is not shipped over the links at double width.
        """
        leaves = jax.tree_util.tree_leaves(tree)
        lead = self.leading(tree, flat=False)
        flats = [l.astype(dtype).reshape(lead + (-1,)) for l in leaves]
        pad = self.padded - self.n_elem
        if pad:
            flats.append(jnp.zeros(lead + (pad,), dtype))
        return jnp.concatenate(flats, axis=-1).reshape(
            lead + (self.rows, LANE))

    def wire_dtype(self):
        """The single dtype all leaves share, or f32 for mixed trees —
        what the flat gossip collectives put on the links."""
        uniq = set(self.dtypes)
        return self.dtypes[0] if len(uniq) == 1 else np.dtype(np.float32)

    def unflatten(self, flat) -> Any:
        """(lead + (T, 128)) buffer -> pytree of per-leaf views.

        Static slices at precomputed offsets; per-leaf dtypes restored from
        metadata.  No concatenate — cheap enough to sit inside the train
        step.  Differentiable with a custom VJP: the cotangent is scattered
        back into ONE flat buffer with in-place dynamic-update-slices
        (XLA's default transpose — a pad-and-add per leaf — costs several
        extra full passes over the model and was measurably slower)."""
        return _unflatten_diff(self, flat)

    def _unflatten_impl(self, flat) -> Any:
        lead = self.leading(flat, flat=True)
        v = flat.reshape(lead + (self.padded,))
        leaves = [
            v[..., off:off + sz].reshape(lead + shape).astype(dtype)
            for off, sz, shape, dtype in zip(self.offsets, self.sizes,
                                             self.shapes, self.dtypes)
        ]
        return jax.tree_util.tree_unflatten(self.treedef, leaves)

    def scatter(self, tree) -> jnp.ndarray:
        """Pytree -> flat buffer via in-place slice updates (no concatenate).

        The transpose of ``unflatten`` (pad region identically zero); also
        handy wherever a tree of per-leaf values must land in the flat
        layout without a parameter-sized concatenate.  Skips None /
        float0 leaves (non-differentiable cotangents); None nodes are kept
        in the traversal (is_leaf) so offsets stay aligned with the
        metadata."""
        leaves = jax.tree_util.tree_leaves(tree, is_leaf=lambda x: x is None)
        lead = self.leading(tree, flat=False)
        v = jnp.zeros(lead + (self.padded,), jnp.float32)
        for leaf, off, sz in zip(leaves, self.offsets, self.sizes):
            if leaf is None or leaf.dtype == jax.dtypes.float0:
                continue
            v = v.at[..., off:off + sz].set(
                leaf.astype(jnp.float32).reshape(lead + (-1,)))
        return v.reshape(lead + (self.rows, LANE))


@partial(jax.custom_vjp, nondiff_argnums=(0,))
def _unflatten_diff(meta: FlatMeta, flat):
    return meta._unflatten_impl(flat)


def _unflatten_fwd(meta, flat):
    return meta._unflatten_impl(flat), None


def _unflatten_bwd(meta, _, ct):
    return (meta.scatter(ct),)


_unflatten_diff.defvjp(_unflatten_fwd, _unflatten_bwd)


@lru_cache(maxsize=64)
def _meta_cached(treedef, shapes, dtypes) -> FlatMeta:
    sizes = tuple(int(np.prod(s, dtype=np.int64)) if s else 1 for s in shapes)
    offsets, off = [], 0
    for sz in sizes:
        offsets.append(off)
        off += sz
    rows = -(-off // LANE)
    rows += (-rows) % ROW_ALIGN
    return FlatMeta(treedef, shapes, dtypes, sizes, tuple(offsets), off, rows)


def flat_meta(tree) -> FlatMeta:
    """Cached FlatMeta for ``tree``'s structure (works on tracers too)."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    shapes = tuple(tuple(l.shape) for l in leaves)
    dtypes = tuple(np.dtype(l.dtype) for l in leaves)
    return _meta_cached(treedef, shapes, dtypes)


# ---------------------------------------------------------------------------
# jaxpr audit: prove the hot step carries no parameter-sized concatenate
# ---------------------------------------------------------------------------

def max_concat_elems(closed_jaxpr) -> int:
    """Largest ``concatenate`` output (in elements) anywhere in the jaxpr.

    The implementation moved to ``repro.analysis.jaxpr_audit`` when the
    ad-hoc check grew into the rule framework (DESIGN §16); this delegate
    keeps the original import path for the tier-1 guard test and the bench
    harness.  Imported lazily so core stays importable without analysis.
    """
    from repro.analysis.jaxpr_audit import max_concat_elems as _impl
    return _impl(closed_jaxpr)
