"""Core: the paper's contribution — decentralized multi-learner SGD with
landscape-dependent self-adjusting effective learning rate."""
from .dpsgd import (AlgoConfig, mix_einsum, mix_ppermute_ring,
                    mix_ppermute_pair, mix_pair_gather, straggler_active_mask)
from .topology import (full_matrix, ring_matrix, torus_matrix, pair_partners,
                       random_pair_matrix, hierarchical_matrix,
                       exponential_matrix, is_doubly_stochastic, spectral_gap,
                       make_mixing_fn)
from .schedule import (GossipSchedule, make_schedule, reschedule,
                       spectral_gap_profile,
                       SCHEDULED_TOPOLOGIES, DETERMINISTIC_TOPOLOGIES)
from .flatstate import FlatMeta, flat_meta, max_concat_elems
from .trainer import MultiLearnerTrainer, ProbeHook, TrainState, StepMetrics
from .membership import Membership, MemberState, admit
from .faults import (FaultEvent, FaultPlan, FaultReport, Supervisor,
                     apply_plan)
from .diagnostics import DiagStats, compute_diagnostics
from .smoothing import smoothed_loss, estimate_smoothness
from .util import (learner_mean, learner_var, masked_learner_mean,
                   masked_learner_var)

__all__ = [
    "AlgoConfig", "mix_einsum", "mix_ppermute_ring", "mix_ppermute_pair",
    "mix_pair_gather", "pair_partners", "straggler_active_mask",
    "full_matrix", "ring_matrix", "torus_matrix", "random_pair_matrix",
    "hierarchical_matrix", "exponential_matrix", "is_doubly_stochastic",
    "spectral_gap", "make_mixing_fn",
    "GossipSchedule", "make_schedule", "reschedule", "spectral_gap_profile",
    "SCHEDULED_TOPOLOGIES", "DETERMINISTIC_TOPOLOGIES",
    "MultiLearnerTrainer", "ProbeHook", "TrainState",
    "StepMetrics", "FlatMeta", "flat_meta", "max_concat_elems",
    "Membership", "MemberState", "admit",
    "FaultEvent", "FaultPlan", "FaultReport", "Supervisor", "apply_plan",
    "DiagStats", "compute_diagnostics", "smoothed_loss", "estimate_smoothness",
    "learner_mean", "learner_var", "masked_learner_mean",
    "masked_learner_var",
]
