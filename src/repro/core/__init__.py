"""Core: the paper's contribution — decentralized multi-learner SGD with
landscape-dependent self-adjusting effective learning rate."""
from .diagnostics import DiagStats, compute_diagnostics
from .dpsgd import (AlgoConfig, mix_einsum, mix_pair_gather,
                    mix_ppermute_pair, mix_ppermute_ring,
                    straggler_active_mask)
from .faults import (FaultEvent, FaultPlan, FaultReport, Supervisor,
                     apply_plan)
from .flatstate import FlatMeta, flat_meta, max_concat_elems
from .membership import Membership, MemberState, admit
from .schedule import (DETERMINISTIC_TOPOLOGIES, SCHEDULED_TOPOLOGIES,
                       GossipSchedule, make_schedule, reschedule,
                       spectral_gap_profile)
from .smoothing import estimate_smoothness, smoothed_loss
from .topology import (exponential_matrix, full_matrix, hierarchical_matrix,
                       is_doubly_stochastic, make_mixing_fn, pair_partners,
                       random_pair_matrix, ring_matrix, spectral_gap,
                       torus_matrix)
from .trainer import MultiLearnerTrainer, ProbeHook, StepMetrics, TrainState
from .util import (learner_mean, learner_var, masked_learner_mean,
                   masked_learner_var)

__all__ = [
    "AlgoConfig", "mix_einsum", "mix_ppermute_ring", "mix_ppermute_pair",
    "mix_pair_gather", "pair_partners", "straggler_active_mask",
    "full_matrix", "ring_matrix", "torus_matrix", "random_pair_matrix",
    "hierarchical_matrix", "exponential_matrix", "is_doubly_stochastic",
    "spectral_gap", "make_mixing_fn",
    "GossipSchedule", "make_schedule", "reschedule", "spectral_gap_profile",
    "SCHEDULED_TOPOLOGIES", "DETERMINISTIC_TOPOLOGIES",
    "MultiLearnerTrainer", "ProbeHook", "TrainState",
    "StepMetrics", "FlatMeta", "flat_meta", "max_concat_elems",
    "Membership", "MemberState", "admit",
    "FaultEvent", "FaultPlan", "FaultReport", "Supervisor", "apply_plan",
    "DiagStats", "compute_diagnostics", "smoothed_loss", "estimate_smoothness",
    "learner_mean", "learner_var", "masked_learner_mean",
    "masked_learner_var",
]
