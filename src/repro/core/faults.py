"""Deterministic fault injection + supervision for elastic fleets (DESIGN §15).

Robustness claims need faults you can replay: a :class:`FaultPlan` is a
seedable, fully-deterministic script of membership faults (crash at step
s, rejoin at step t, slow-node, wedged-node, dropped gossip round) that
the same seed reproduces bit-for-bit — the single source of truth for the
vmap-trainer harness, the launch-path harness and the straggler benchmark
(fig3 injects its slow learner through the same plan).

The :class:`Supervisor` is the host-side control loop that a production
deployment would run next to the fleet:

  * it applies the plan's scripted faults (the "chaos monkey" half), and
  * it DETECTS wedged learners it was never told about: a member whose
    progress clock stalls past ``staleness_bound * grace`` ticks gets a
    bounded number of recovery retries with doubling backoff windows, and
    is evicted (→ ``Membership.crash`` → reschedule) when they run out.

Detection reads the trainer's own per-learner ``clock`` (AD-PSGD threads
one through the state); for synchronous DPSGD — where a wedged learner is
unobservable from the lockstep state — progress is inferred from the
membership's tick divisors, which is exactly the information a heartbeat
side channel would carry.  Every intervention lands as a
``set_membership`` operand swap, so the compiled step is never invalidated.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, NamedTuple, Optional, Tuple

import numpy as np

from .membership import HUNG, Membership, admit

__all__ = ["FaultEvent", "FaultPlan", "FaultReport", "Supervisor",
           "apply_plan"]

KINDS = ("crash", "rejoin", "slow", "recover", "hang", "drop_round")


class FaultEvent(NamedTuple):
    """One scripted fault.  ``arg``: slow-every divisor for ``slow``,
    truthy = sticky (recovery-proof) for ``hang``, unused otherwise.
    ``learner`` is ignored for ``drop_round`` (it is fleet-wide)."""
    step: int
    kind: str
    learner: int = 0
    arg: Any = None


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """An immutable, replayable schedule of faults (sorted by step)."""
    events: Tuple[FaultEvent, ...] = ()

    def __post_init__(self):
        for ev in self.events:
            assert ev.kind in KINDS, ev.kind
        object.__setattr__(self, "events",
                           tuple(sorted(self.events, key=lambda e: e.step)))

    def at(self, step: int) -> List[FaultEvent]:
        return [ev for ev in self.events if ev.step == step]

    @property
    def last_step(self) -> int:
        return max((ev.step for ev in self.events), default=-1)

    # -- canned plans ---------------------------------------------------------
    @staticmethod
    def straggler(learner: int, every: int, start: int = 0) -> "FaultPlan":
        """A permanently slow node — fig3's injected straggler, now one
        seeded code path with the rest of the fault harness."""
        return FaultPlan((FaultEvent(start, "slow", learner, every),))

    @staticmethod
    def crash_rejoin(learner: int, crash_at: int,
                     rejoin_at: Optional[int] = None) -> "FaultPlan":
        evs = [FaultEvent(crash_at, "crash", learner)]
        if rejoin_at is not None:
            assert rejoin_at > crash_at, (crash_at, rejoin_at)
            evs.append(FaultEvent(rejoin_at, "rejoin", learner))
        return FaultPlan(tuple(evs))

    @staticmethod
    def random(seed: int, steps: int, capacity: int, *,
               p_crash: float = 0.02, p_rejoin: float = 0.3,
               p_slow: float = 0.02, p_drop: float = 0.02,
               min_active: int = 2) -> "FaultPlan":
        """A seeded chaos schedule.  Deterministic: same seed, same plan.
        Never drives the simulated fleet below ``min_active`` live members
        (a fleet of dead learners is not an interesting failure mode)."""
        rng = np.random.default_rng(seed)
        active = np.ones(capacity, bool)
        evs: List[FaultEvent] = []
        for step in range(steps):
            if rng.random() < p_drop:
                evs.append(FaultEvent(step, "drop_round"))
            if active.sum() > min_active and rng.random() < p_crash:
                i = int(rng.choice(np.flatnonzero(active)))
                evs.append(FaultEvent(step, "crash", i))
                active[i] = False
            if (~active).any() and rng.random() < p_rejoin:
                i = int(rng.choice(np.flatnonzero(~active)))
                evs.append(FaultEvent(step, "rejoin", i))
                active[i] = True
            if active.sum() > min_active and rng.random() < p_slow:
                i = int(rng.choice(np.flatnonzero(active)))
                evs.append(FaultEvent(step, "slow", i,
                                      int(rng.integers(2, 5))))
        return FaultPlan(tuple(evs))


@dataclasses.dataclass
class FaultReport:
    """What the supervisor did, step-stamped — the benchmark's raw
    material for recovery-time measurement."""
    crashes: List[Tuple[int, int]] = dataclasses.field(default_factory=list)
    rejoins: List[Tuple[int, int]] = dataclasses.field(default_factory=list)
    retries: List[Tuple[int, int]] = dataclasses.field(default_factory=list)
    evictions: List[Tuple[int, int]] = dataclasses.field(default_factory=list)
    dropped_rounds: int = 0

    @property
    def interventions(self) -> int:
        return (len(self.crashes) + len(self.rejoins) + len(self.retries)
                + len(self.evictions))


def apply_plan(membership: Membership, plan: FaultPlan, step: int, *,
               on_rejoin=None, sticky: Optional[set] = None,
               report: Optional[FaultReport] = None) -> bool:
    """Apply the plan's scripted events due at ``step`` to a Membership.

    The ONE seeded injection path shared by the vmap-trainer Supervisor
    and the launch (pjit/shard_map) harness.  ``on_rejoin(slot)`` runs
    BEFORE the mask flips live (state surgery — e.g. :func:`admit` —
    must clone the consensus of the pre-join active set).  Returns True
    if this step's gossip round is dropped.
    """
    drop = False
    for ev in plan.at(step):
        if ev.kind == "crash" and membership.active[ev.learner]:
            membership.crash(ev.learner)
            if sticky is not None:
                sticky.discard(ev.learner)
            if report is not None:
                report.crashes.append((step, ev.learner))
        elif ev.kind == "rejoin" and not membership.active[ev.learner]:
            if on_rejoin is not None:
                on_rejoin(ev.learner)
            membership.rejoin(ev.learner)
            if report is not None:
                report.rejoins.append((step, ev.learner))
        elif ev.kind == "slow":
            membership.set_slow(ev.learner, int(ev.arg))
        elif ev.kind == "hang":
            membership.hang(ev.learner)
            if ev.arg and sticky is not None:
                sticky.add(ev.learner)
        elif ev.kind == "recover":
            if sticky is not None:
                sticky.discard(ev.learner)
            membership.recover(ev.learner)
        elif ev.kind == "drop_round":
            drop = True
            if report is not None:
                report.dropped_rounds += 1
    return drop


@dataclasses.dataclass
class Supervisor:
    """Host-side fleet supervision: scripted fault injection + wedge
    detection with bounded retry/backoff, over an elastic trainer.

    ``tick(state, step)`` runs BEFORE the step's ``train_step`` call and
    returns the (possibly membership-swapped) state.  Wedge policy: a
    live learner silent for more than ``staleness_bound * grace *
    2**retries`` supervisor ticks gets a recovery attempt (the doubling
    factor is the backoff — each failed retry earns the learner a longer
    leash), and is evicted once ``max_retries`` attempts are spent.
    """
    trainer: Any
    membership: Membership
    plan: FaultPlan = dataclasses.field(default_factory=FaultPlan)
    staleness_bound: int = 4
    grace: int = 2
    max_retries: int = 2
    admit_mode: str = "consensus"

    report: FaultReport = dataclasses.field(default_factory=FaultReport)

    def __post_init__(self):
        cap = self.membership.capacity
        self._last_clock = np.zeros(cap, np.int64)
        self._stall = np.zeros(cap, np.int64)
        self._retries = np.zeros(cap, np.int64)
        self._sticky = set()           # recovery-proof (truly wedged) hangs
        self._dropped = False          # last tick's drop_round flag

    # -- one supervision tick -------------------------------------------------
    def tick(self, state, step: int):
        mem = self.membership
        epoch0 = mem.epoch
        box = [state]

        def on_rejoin(slot):
            # surgery first (clones the consensus of the CURRENT live
            # set), then the mask flip — order matters
            box[0] = admit(self.trainer, box[0], slot, mode=self.admit_mode)
            self._stall[slot] = 0
            self._retries[slot] = 0
            self._last_clock[slot] = 0          # admit zeroed the clock

        drop = apply_plan(mem, self.plan, step, on_rejoin=on_rejoin,
                          sticky=self._sticky, report=self.report)
        state = box[0]

        self._detect(state, step)

        if mem.epoch != epoch0 or drop or self._dropped:
            state = self.trainer.set_membership(state, mem, drop_round=drop)
        self._dropped = drop
        return state

    def _detect(self, state, step: int) -> None:
        """Stall accounting + the retry/backoff/evict ladder."""
        mem = self.membership
        clock = getattr(state, "clock", None)
        if clock is not None:          # AD-PSGD: real per-learner progress
            # wedge detection must read real device progress, once per
            # supervisor tick — an intentional sync
            c = np.asarray(clock)                # lint: allow-host-sync
            advanced = c > self._last_clock
            self._last_clock = np.maximum(self._last_clock, c)
        else:                          # sync DPSGD: heartbeat-equivalent
            se = mem.slow_every
            advanced = (mem.active & (se < HUNG)
                        & (step % np.maximum(se, 1) == 0))
        self._stall = np.where(advanced | ~mem.active, 0, self._stall + 1)
        base = self.staleness_bound * self.grace
        for i in np.flatnonzero(mem.active):
            if self._stall[i] <= base * (1 << int(self._retries[i])):
                continue
            if self._retries[i] < self.max_retries:
                self._retries[i] += 1
                self.report.retries.append((step, int(i)))
                if i not in self._sticky:      # transient wedge: unstick it
                    mem.recover(int(i))
            else:
                mem.crash(int(i))
                self._sticky.discard(int(i))
                self._stall[i] = 0
                self._retries[i] = 0
                self.report.evictions.append((step, int(i)))

    # -- convenience driver ---------------------------------------------------
    def run(self, state, batch_fn, steps: int, start: int = 0):
        """Supervised loop: tick, step, repeat.  ``batch_fn(i)`` feeds the
        stacked batch for host step ``i``.  Returns (state, losses)."""
        losses = []
        for i in range(start, start + steps):
            state = self.tick(state, i)
            state, m = self.trainer.train_step(state, batch_fn(i))
            losses.append(float(m.loss))
        return state, losses
