"""Pallas TPU kernel: blocked flash attention (causal / sliding-window /
logit-softcap, GQA-aware).

Grid: (B, KV, G, nq, nk) — the innermost nk axis is sequential ("arbitrary"
semantics) and accumulates the online softmax in VMEM scratch, writing the
output tile on the last nk step.  BlockSpecs tile q/k/v into
(block_q, head_dim) / (block_k, head_dim) VMEM tiles; head_dim is MXU-lane
aligned (128 for every assigned config; 64 for the small ones — still a
multiple of the 8x128 f32 tile after padding by Mosaic).

Positions are implicit: q row = iq*bq + lane, k row = ik*bk + lane (training/
prefill layouts are contiguous from 0).  The causal/window masking is
computed in-kernel from the grid indices, so fully-masked (iq, ik) tiles
cost one predicated vector op, not a matmul (the jnp reference cannot skip
them — that is the kernel's win besides fusion).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax 0.4.x names it TPUCompilerParams; >= 0.6 renamed to CompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                 block_q: int, block_k: int, n_k_blocks: int, scale: float,
                 causal: bool, window: int, attn_softcap: float):
    iq = pl.program_id(3)
    ik = pl.program_id(4)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0, :, :].astype(jnp.float32)       # (bq, hd)
    k = k_ref[0, 0, :, :].astype(jnp.float32)       # (bk, hd)
    v = v_ref[0, 0, :, :].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if attn_softcap:
        s = attn_softcap * jnp.tanh(s / attn_softcap)

    qpos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 0)
    kpos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        mask &= qpos >= kpos
    if window:
        mask &= qpos - kpos < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ik == n_k_blocks - 1)
    def _finalize():
        o_ref[0, 0, :, :] = (acc_scr[...]
                             / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "attn_softcap", "block_q",
                              "block_k", "interpret"))
def flash_attention_fwd(q, k, v, *, causal: bool = True, window: int = 0,
                        attn_softcap: float = 0.0, block_q: int = 128,
                        block_k: int = 128, interpret: bool = False):
    """q: (B, H, Sq, hd); k, v: (B, KV, Sk, hd) -> (B, H, Sq, hd)."""
    B, H, Sq, hd = q.shape
    _, KV, Sk, _ = k.shape
    G = H // KV
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    assert Sq % bq == 0 and Sk % bk == 0
    nq, nk = Sq // bq, Sk // bk
    grid = (B, KV, G, nq, nk)
    kern = functools.partial(
        _attn_kernel, block_q=bq, block_k=bk, n_k_blocks=nk,
        scale=hd ** -0.5, causal=causal, window=window,
        attn_softcap=attn_softcap)

    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd),
                         lambda b, kv, g, iq, ik: (b, kv * G + g, iq, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, kv, g, iq, ik: (b, kv, ik, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, kv, g, iq, ik: (b, kv, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd),
                               lambda b, kv, g, iq, ik: (b, kv * G + g, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
