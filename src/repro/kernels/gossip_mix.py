"""Pallas TPU kernel: fused gossip-mix + momentum-SGD update.

The DPSGD inner loop per learner i is

    mixed_i = sum_j M_ij w_j            (neighbor average; j ranges over the
                                         few non-zero mixing weights)
    mu_i    = beta * mu_i + g_i         (momentum)
    w_i     = mixed_i - lr * mu_i

Unfused, XLA emits three separate HBM-bound passes over the full parameter
vector (mix read/write, momentum read/write, apply read/write) ≈ 8P moves.
The fused kernel streams each (8,128)-aligned block of {w_self, w_neighbors,
g, mu} through VMEM once and writes {w_new, mu_new}: ≈ (3+k)P moves, a
~2.2x HBM-traffic cut on the op that IS the paper's technique (arithmetic
intensity < 1 flop/byte — pure bandwidth).

Layout: the parameter pytree is flattened to a (T, 128) f32 view (padded);
neighbor copies arrive as (K, T, 128) — on a real pod these are the
ppermute-received buffers, here they are explicit inputs so the kernel is
topology-agnostic (K = #non-zero off-diagonal mixing weights, usually 1-2).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
BLOCK_ROWS = 256          # (256, 128) f32 block = 128 KiB / buffer in VMEM


def _kernel(w_ref, nbr_ref, g_ref, mu_ref, coef_ref, w_out_ref, mu_out_ref,
            *, n_neighbors: int, lr: float, beta: float):
    """One (BLOCK_ROWS, LANE) tile.

    coef_ref: (1 + K,) f32 in SMEM — [self_coef, neighbor coefs...].
    """
    w = w_ref[...]
    mixed = coef_ref[0] * w
    for k in range(n_neighbors):
        mixed += coef_ref[k + 1] * nbr_ref[k]
    mu_new = beta * mu_ref[...] + g_ref[...]
    w_out_ref[...] = mixed - lr * mu_new
    mu_out_ref[...] = mu_new


@functools.partial(jax.jit,
                   static_argnames=("lr", "beta", "interpret", "block_rows"))
def gossip_mix_update(w, neighbors, grads, momentum, coefs, *, lr: float,
                      beta: float = 0.9, interpret: bool = False,
                      block_rows: int = BLOCK_ROWS):
    """w, grads, momentum: (T, 128) f32; neighbors: (K, T, 128);
    coefs: (1 + K,) f32 mixing weights (self first).  Returns (w_new, mu_new).
    """
    T, lane = w.shape
    assert lane == LANE, lane
    K = neighbors.shape[0]
    rows = min(block_rows, T)
    assert T % rows == 0, (T, rows)
    grid = (T // rows,)

    kern = functools.partial(_kernel, n_neighbors=K, lr=lr, beta=beta)
    block = pl.BlockSpec((rows, LANE), lambda i: (i, 0))
    nbr_block = pl.BlockSpec((K, rows, LANE), lambda i: (0, i, 0))
    coef_block = pl.BlockSpec((K + 1,), lambda i: (0,))

    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[block, nbr_block, block, block, coef_block],
        out_specs=[block, block],
        out_shape=[jax.ShapeDtypeStruct((T, LANE), w.dtype),
                   jax.ShapeDtypeStruct((T, LANE), momentum.dtype)],
        interpret=interpret,
    )(w, neighbors, grads, momentum, coefs)


# ---------------------------------------------------------------------------
# pytree-level wrapper: flatten -> kernel -> unflatten
# ---------------------------------------------------------------------------

def flatten_for_kernel(tree):
    """Pytree -> ((T,128) f32 view, unflatten_fn)."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    sizes = [l.size for l in leaves]
    flat = jnp.concatenate([l.astype(jnp.float32).reshape(-1) for l in leaves])
    pad = (-flat.size) % LANE
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), jnp.float32)])
    view = flat.reshape(-1, LANE)

    def unflatten(view2):
        flat2 = view2.reshape(-1)[:sum(sizes)]
        out, off = [], 0
        for l, sz in zip(leaves, sizes):
            out.append(flat2[off:off + sz].reshape(l.shape).astype(l.dtype))
            off += sz
        return jax.tree_util.tree_unflatten(treedef, out)

    return view, unflatten
