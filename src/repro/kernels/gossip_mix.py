"""Pallas TPU kernel: fused gossip-mix + momentum-SGD update.

The DPSGD inner loop per learner i is

    mixed_i = sum_j M_ij w_j            (neighbor average; j ranges over the
                                         few non-zero mixing weights)
    mu_i    = beta * mu_i + g_i         (momentum)
    w_i     = mixed_i - lr * mu_i

Unfused, XLA emits three separate HBM-bound passes over the full parameter
vector (mix read/write, momentum read/write, apply read/write) ≈ 8P moves.
The fused kernel streams each (8,128)-aligned block of {w_self, w_neighbors,
g, mu} through VMEM once and writes {w_new, mu_new}: ≈ (3+k)P moves, a
~2.2x HBM-traffic cut on the op that IS the paper's technique (arithmetic
intensity < 1 flop/byte — pure bandwidth).

Layout: the parameter pytree is flattened to a (T, 128) f32 view (padded);
neighbor copies arrive as (K, T, 128) — on a real pod these are the
ppermute-received buffers, here they are explicit inputs so the kernel is
topology-agnostic (K = #non-zero off-diagonal mixing weights — any static
K: the compiled GossipSchedule tables in core/schedule.py pad every round
to one fixed neighbor count, so pair matchings, rings, tori, exponential
graphs and hierarchical rounds all dispatch the same kernel, DESIGN §12).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.flatstate import flat_meta

LANE = 128
BLOCK_ROWS = 256          # (256, 128) f32 block = 128 KiB / buffer in VMEM


def _kernel(w_ref, nbr_ref, g_ref, mu_ref, coef_ref, w_out_ref, mu_out_ref,
            *, n_neighbors: int, lr: float, beta: float):
    """One (BLOCK_ROWS, LANE) tile.

    coef_ref: (1 + K,) f32 in SMEM — [self_coef, neighbor coefs...].
    """
    w = w_ref[...]
    mixed = coef_ref[0] * w
    for k in range(n_neighbors):
        mixed += coef_ref[k + 1] * nbr_ref[k]
    mu_new = beta * mu_ref[...] + g_ref[...]
    w_out_ref[...] = mixed - lr * mu_new
    mu_out_ref[...] = mu_new


@functools.partial(jax.jit,
                   static_argnames=("lr", "beta", "interpret", "block_rows"))
def gossip_mix_update(w, neighbors, grads, momentum, coefs, *, lr: float,
                      beta: float = 0.9, interpret: bool = False,
                      block_rows: int = BLOCK_ROWS):
    """w, grads, momentum: (T, 128) f32; neighbors: (K, T, 128);
    coefs: (1 + K,) f32 mixing weights (self first).  Returns (w_new, mu_new).
    """
    T, lane = w.shape
    assert lane == LANE, lane
    K = neighbors.shape[0]
    rows = _pick_rows(T, block_rows)
    grid = (T // rows,)

    kern = functools.partial(_kernel, n_neighbors=K, lr=lr, beta=beta)
    block = pl.BlockSpec((rows, LANE), lambda i: (i, 0))
    nbr_block = pl.BlockSpec((K, rows, LANE), lambda i: (0, i, 0))
    coef_block = pl.BlockSpec((K + 1,), lambda i: (0,))

    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[block, nbr_block, block, block, coef_block],
        out_specs=[block, block],
        out_shape=[jax.ShapeDtypeStruct((T, LANE), w.dtype),
                   jax.ShapeDtypeStruct((T, LANE), momentum.dtype)],
        interpret=interpret,
    )(w, neighbors, grads, momentum, coefs)


# ---------------------------------------------------------------------------
# batched (learner-major) kernel: the flat-engine hot path
# ---------------------------------------------------------------------------
#
# One pallas_call updates ALL n learners: grid (n, T // rows), learner-major.
# The K neighbor operands are not gathered on the host — the partner indices
# ride in as scalar-prefetch operands and the neighbor BlockSpec index_map
# reads its learner row straight out of the published/remote buffer
# (``partners[k, i]``), so the only parameter-sized HBM traffic is the
# streamed blocks themselves: (3 + K) reads + 2 writes per element, with the
# momentum-SGD update (optional weight decay, per-learner lr scale for the
# AutoLR controller) fused into the same pass.

def _flat_kernel(part_ref, *refs, n_neighbors: int, lr: float, beta: float,
                 weight_decay: float, has_momentum: bool, publish: bool):
    """One (1, rows, LANE) tile of one learner.

    refs layout:
      w, nbr_w_0..K-1, [nbr_buf], g, [mu], [buf], coefs,
      w_out, [mu_out], [buf_out]
    coefs (SMEM): [self, neighbor..., controller scale, active] — plus, in
    publish mode, [nbr_fresh, publish].

    ``active`` (0/1) folds the AD-PSGD straggler select into the same pass:
    an inactive learner's weights and momentum stream through unchanged
    instead of costing two extra full-buffer select passes outside the
    kernel (sync paths pass 1).  ``publish`` mode (AD-PSGD, K=1) further
    folds the whole async tick in: the neighbor contribution is selected
    between the partner's live weights and its stale published buffer
    (``nbr_fresh``), and the learner's own published buffer is rewritten
    in-pass (``publish`` flag = active | forced-fresh) — the tick touches
    each parameter exactly once instead of three more select passes.
    """
    k = n_neighbors
    it = iter(refs)
    w_ref = next(it)
    nbr_refs = [next(it) for _ in range(k)]
    nbr_buf_ref = next(it) if publish else None
    g_ref = next(it)
    mu_ref = next(it) if has_momentum else None
    buf_ref = next(it) if publish else None
    coef_ref = next(it)
    w_out = next(it)
    mu_out = next(it) if has_momentum else None
    buf_out = next(it) if publish else None

    w = w_ref[0]
    mixed = coef_ref[0, 0] * w
    for j in range(k):
        nbr = nbr_refs[j][0]
        if publish:
            nbr = jnp.where(coef_ref[0, 3 + k] > 0.5, nbr, nbr_buf_ref[0])
        mixed += coef_ref[0, 1 + j] * nbr
    g = g_ref[0]
    if weight_decay:
        g = g + weight_decay * w
    lr_eff = lr * coef_ref[0, 1 + k]
    # where, not arithmetic blend: a mid-divergence NaN in the discarded
    # branch must not leak through 0 * NaN
    active = coef_ref[0, 2 + k] > 0.5
    if has_momentum:
        mu = mu_ref[0]
        mu_new = beta * mu + g
        new_w = jnp.where(active, mixed - lr_eff * mu_new, w)
        mu_out[0] = jnp.where(active, mu_new, mu)
    else:
        new_w = jnp.where(active, mixed - lr_eff * g, w)
    w_out[0] = new_w
    if publish:
        buf_out[0] = jnp.where(coef_ref[0, 4 + k] > 0.5, new_w, buf_ref[0])


def _pick_rows(T: int, block_rows: int) -> int:
    """Largest sublane-aligned divisor of T that fits block_rows.

    Flat-store T is always a multiple of 8 (flatstate.ROW_ALIGN), so an
    8-aligned divisor exists (8 itself at worst); small ad-hoc T (tests,
    tree wrapper) falls back to any divisor."""
    r = min(block_rows, T)
    while r > 8 and (T % r or r % 8):
        r -= 1
    while T % r:
        r -= 1
    return r


@functools.partial(
    jax.jit, static_argnames=("lr", "beta", "weight_decay", "has_momentum",
                              "interpret", "block_rows"))
def gossip_mix_update_flat(w, remote, grads, momentum, partners, coefs, *,
                           lr: float, beta: float = 0.0,
                           weight_decay: float = 0.0,
                           has_momentum: bool = True,
                           buffer=None,
                           interpret: bool = False,
                           block_rows: int = BLOCK_ROWS):
    """Batched fused gossip + SGD update on the persistent flat store.

    w, grads: (n, T, 128) f32 live weights / gradients.
    remote:   (n, T, 128) buffer neighbor contributions are read from (the
              live weights for synchronous DPSGD — pass ``w`` itself to
              alias them).
    momentum: (n, T, 128) or ignored when ``has_momentum=False``.
    partners: (K, n) int32 — neighbor learner index per schedule row,
              consumed via scalar prefetch.  K is any static neighbor
              count: pair matching K=1, ring K=2, torus K=4, static
              exponential K=ceil(log2 n), full-as-one-round K=n-1 — one
              row of a compiled core/schedule.GossipSchedule (padded
              self-loop slots carry coefficient 0).
    coefs:    (n, K + 3) f32 — [self, neighbor..., lr scale, active] per
              learner: a solo learner carries [1, 0, ...]; ``lr scale`` is
              the controller/schedule multiplier (one compiled kernel
              serves every scale value); ``active`` (0/1) applies the
              AD-PSGD straggler select in the same pass (1 for sync paths).
    buffer:   (n, T, 128) published-weights buffer — enables the AD-PSGD
              publish mode (K=1): coefs grows two columns [nbr_fresh,
              publish]; the neighbor contribution reads
              ``where(nbr_fresh, remote[partner], buffer[partner])`` and a
              third output returns ``where(publish, w_new, buffer)`` — the
              whole async tick in one parameter pass.
    Returns (w_new, mu_new[, buffer_new]) — mu_new is ``momentum``
    untouched when ``has_momentum=False``; buffer_new only in publish mode.
    """
    n, T, lane = w.shape
    assert lane == LANE, lane
    K = partners.shape[0]
    publish = buffer is not None
    ncoef = K + (5 if publish else 3)
    assert not publish or K == 1, "publish mode is pairwise (AD-PSGD)"
    assert partners.shape == (K, n), (partners.shape, n)
    assert coefs.shape == (n, ncoef), (coefs.shape, K, publish)
    rows = _pick_rows(T, block_rows)
    grid = (n, T // rows)

    block = pl.BlockSpec((1, rows, LANE), lambda i, j, p: (i, j, 0))

    def nbr_spec(k):
        return pl.BlockSpec((1, rows, LANE), lambda i, j, p: (p[k, i], j, 0))

    coef_spec = pl.BlockSpec((1, ncoef), lambda i, j, p: (i, 0),
                             memory_space=pltpu.SMEM)

    kern = functools.partial(_flat_kernel, n_neighbors=K, lr=lr, beta=beta,
                             weight_decay=weight_decay,
                             has_momentum=has_momentum, publish=publish)
    in_specs = [block] + [nbr_spec(k) for k in range(K)]
    operands = [w] + [remote] * K
    if publish:
        in_specs.append(nbr_spec(0))
        operands.append(buffer)
    in_specs.append(block)
    operands.append(grads)
    out_shape = [jax.ShapeDtypeStruct((n, T, LANE), w.dtype)]
    out_specs = [block]
    if has_momentum:
        in_specs.append(block)
        operands.append(momentum)
        out_shape.append(jax.ShapeDtypeStruct((n, T, LANE), jnp.float32))
        out_specs.append(block)
    if publish:
        in_specs.append(block)
        operands.append(buffer)
        out_shape.append(jax.ShapeDtypeStruct((n, T, LANE), w.dtype))
        out_specs.append(block)
    in_specs.append(coef_spec)
    operands.append(coefs)

    out = pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1, grid=grid,
            in_specs=in_specs, out_specs=out_specs),
        out_shape=out_shape,
        interpret=interpret,
    )(partners, *operands)
    mu_new = out[1] if has_momentum else momentum
    if publish:
        return out[0], mu_new, out[-1]
    return out[0], mu_new


# ---------------------------------------------------------------------------
# pytree-level wrapper: flatten -> kernel -> unflatten
# ---------------------------------------------------------------------------

def flatten_for_kernel(tree):
    """Pytree -> ((T,128) f32 view, unflatten_fn).

    Thin shim over core.flatstate.FlatMeta (used by landscape/lanczos.py and
    the one-shot kernel wrappers): the metadata — per-leaf dtypes, sizes and
    offsets — is computed once per structure and cached, so repeated calls
    stop rebuilding offset lists; unflatten restores each leaf's original
    dtype from that metadata.  The flatten itself still concatenates — the
    flat *engine* (core/trainer.py) avoids even that by keeping the flat
    buffer persistent across steps.
    """
    meta = flat_meta(tree)
    return meta.flatten(tree), meta.unflatten
