from .ops import (flash_attention, dpsgd_fused_update, flat_gossip_update,
                  reorthogonalize)
from .gossip_mix import (gossip_mix_update, gossip_mix_update_flat,
                         flatten_for_kernel)
from .flash_attention import flash_attention_fwd
from .reorth import reorth_pass, reorth_dots, reorth_axpy
from . import ref

__all__ = ["flash_attention", "dpsgd_fused_update", "flat_gossip_update",
           "gossip_mix_update", "gossip_mix_update_flat",
           "flatten_for_kernel", "flash_attention_fwd", "reorthogonalize",
           "reorth_pass", "reorth_dots", "reorth_axpy", "ref"]
