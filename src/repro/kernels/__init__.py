from . import ref
from .flash_attention import flash_attention_fwd
from .gossip_mix import (flatten_for_kernel, gossip_mix_update,
                         gossip_mix_update_flat)
from .ops import (dpsgd_fused_update, flash_attention, flat_gossip_update,
                  reorthogonalize)
from .reorth import reorth_axpy, reorth_dots, reorth_pass

__all__ = ["flash_attention", "dpsgd_fused_update", "flat_gossip_update",
           "gossip_mix_update", "gossip_mix_update_flat",
           "flatten_for_kernel", "flash_attention_fwd", "reorthogonalize",
           "reorth_pass", "reorth_dots", "reorth_axpy", "ref"]
