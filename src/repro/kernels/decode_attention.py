"""Pallas TPU kernel: paged decode attention (one query token per slot).

Grown from flash_attention.py for the serving decode grid (ISSUE 7): every
serve slot contributes exactly ONE query token, and its K/V history lives in
fixed-size pages scattered through a shared pool.  The page table rides in
as a scalar-prefetch operand and the K/V BlockSpec index_maps gather each
logical page straight out of the pool (``table[s, j]``) — the same
SMEM-partner idiom gossip_mix.py uses for neighbor rows, so the gather costs
zero extra HBM passes: the kernel streams exactly the pages the slot owns.

Grid: (S, KV, n_pages) with the page axis sequential ("arbitrary"), online
softmax in VMEM scratch exactly like the prefill kernel.  Masking is
computed in-kernel from the page index and the per-slot length (second
scalar-prefetch operand): entry t of logical page j is valid iff
j*page + t < length[s] (and, for sliding-window layers, >= length - window).
Pages wholly past the slot's length still run one predicated vector op, and
the flash rescale trick keeps fully-masked pages from polluting the
accumulator (their contribution is wiped by ``corr`` once a live page is
seen; a length-0 slot degenerates to the same uniform average the oracle
produces — finite garbage the scheduler ignores).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax 0.4.x names it TPUCompilerParams; >= 0.6 renamed to CompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))

NEG_INF = -1e30


def _decode_kernel(table_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                   m_scr, l_scr, acc_scr, *, page: int, n_pages: int,
                   scale: float, window: int, attn_softcap: float):
    s_idx = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)             # (G, hd)
    k = k_ref[0, :, 0, :].astype(jnp.float32)       # (page, hd)
    v = v_ref[0, :, 0, :].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if attn_softcap:
        s = attn_softcap * jnp.tanh(s / attn_softcap)

    length = len_ref[s_idx]
    kpos = j * page + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = kpos < length
    if window:
        mask &= kpos >= length - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(j == n_pages - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...]
                       / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("window", "attn_softcap", "interpret"))
def paged_decode_attention_fwd(q, k_pages, v_pages, page_table, lengths, *,
                               window: int = 0, attn_softcap: float = 0.0,
                               interpret: bool = False):
    """q: (S, H, hd); k_pages, v_pages: (P, page, KV, hd);
    page_table: (S, max_pages) int32; lengths: (S,) int32 -> (S, H, hd)."""
    S, H, hd = q.shape
    P, page, KV, _ = k_pages.shape
    G = H // KV
    max_pages = page_table.shape[1]
    grid = (S, KV, max_pages)

    kern = functools.partial(_decode_kernel, page=page, n_pages=max_pages,
                             scale=hd ** -0.5, window=window,
                             attn_softcap=attn_softcap)
    qg = q.reshape(S, KV, G, hd)

    out = pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2, grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, G, hd),
                             lambda s, kv, j, tbl, ln: (s, kv, 0, 0)),
                pl.BlockSpec((1, page, 1, hd),
                             lambda s, kv, j, tbl, ln: (tbl[s, j], 0, kv, 0)),
                pl.BlockSpec((1, page, 1, hd),
                             lambda s, kv, j, tbl, ln: (tbl[s, j], 0, kv, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, G, hd),
                                   lambda s, kv, j, tbl, ln: (s, kv, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((G, 1), jnp.float32),
                pltpu.VMEM((G, 1), jnp.float32),
                pltpu.VMEM((G, hd), jnp.float32),
            ]),
        out_shape=jax.ShapeDtypeStruct((S, KV, G, hd), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(page_table.astype(jnp.int32), lengths.astype(jnp.int32), qg,
      k_pages, v_pages)
    return out.reshape(S, H, hd)
