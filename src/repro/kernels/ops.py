"""jit'd public wrappers around the Pallas kernels.

`flash_attention` accepts the model-layout (B, S, H, hd) tensors used by
repro.models.attention and adds a custom VJP whose backward pass is the
jnp reference gradient (forward runs the kernel; backward recomputes through
the oracle — numerically identical, documented trade-off).

On CPU (this container) the kernels run in interpret mode automatically;
on TPU they compile to Mosaic.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import ref
from .decode_attention import paged_decode_attention_fwd
from .flash_attention import flash_attention_fwd
from .gossip_mix import (flatten_for_kernel, gossip_mix_update,
                         gossip_mix_update_flat)
from .reorth import reorth_pass


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _flash(q, k, v, causal, window, attn_softcap, q_positions, k_positions):
    # layout: (B, S, H, hd) -> kernel layout (B, H, S, hd)
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    o = flash_attention_fwd(qt, kt, vt, causal=causal, window=window,
                            attn_softcap=attn_softcap, interpret=_on_cpu())
    return o.transpose(0, 2, 1, 3)


def _ref_bsh(q, k, v, causal, window, attn_softcap):
    o = ref.flash_attention_ref(q.transpose(0, 2, 1, 3),
                                k.transpose(0, 2, 1, 3),
                                v.transpose(0, 2, 1, 3),
                                causal=causal, window=window,
                                attn_softcap=attn_softcap)
    return o.transpose(0, 2, 1, 3)


def _flash_fwd(q, k, v, causal, window, attn_softcap, qp, kp):
    return _flash(q, k, v, causal, window, attn_softcap, qp, kp), (q, k, v)


def _flash_bwd(causal, window, attn_softcap, qp, kp, res, g):
    q, k, v = res
    _, vjp = jax.vjp(lambda q_, k_, v_: _ref_bsh(q_, k_, v_, causal, window,
                                                 attn_softcap), q, k, v)
    return vjp(g)


_flash.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(q, k, v, *, q_positions=None, k_positions=None,
                    causal: bool = True, window: int = 0,
                    attn_softcap: float = 0.0):
    """Model-layout flash attention.  q: (B, Sq, H, hd); k, v: (B, Sk, KV, hd).

    Assumes contiguous positions from 0 (training/prefill); the explicit
    position arrays are accepted for API parity with chunked_attention and
    validated when concrete.
    """
    return _flash(q, k, v, causal, window, attn_softcap, q_positions,
                  k_positions)


def paged_decode_attention(q, k_pages, v_pages, page_table, lengths, *,
                           window: int = 0, attn_softcap: float = 0.0,
                           backend: str = "auto"):
    """Paged serving decode attention (ISSUE 7, DESIGN §14).

    q: (S, H, hd) — one query token per serve slot; k_pages, v_pages:
    (P, page, KV, hd) shared pools; page_table: (S, max_pages) int32
    physical page ids in logical order; lengths: (S,) int32 valid tokens
    per slot (current token included).  ``backend='auto'`` follows the
    repo's kernel/oracle/dispatch rule: the Mosaic kernel on accelerators,
    the jnp oracle on CPU (interpret mode exists to *verify* the kernel —
    tests force ``backend='pallas'`` for that).  Inference-only: no VJP.
    """
    if backend == "auto":
        backend = "ref" if _on_cpu() else "pallas"
    if backend == "ref":
        return ref.paged_decode_attention_ref(
            q, k_pages, v_pages, page_table, lengths, window=window,
            attn_softcap=attn_softcap)
    return paged_decode_attention_fwd(
        q, k_pages, v_pages, page_table, lengths, window=window,
        attn_softcap=attn_softcap, interpret=_on_cpu())


def reorthogonalize(basis, w, mask, *, backend: str = "pallas"):
    """Fully reorthogonalize w against the masked basis prefix (DESIGN §10).

    basis: (M, T, 128) stacked flat Lanczos vectors; w: (T, 128) candidate;
    mask: (M,) 0/1 f32 marking the live prefix.  Two classical-Gram-Schmidt
    sweeps (CGS2 — the "twice is enough" rule) through the fused Pallas
    dot/axpy kernels, or through the jnp oracle with ``backend='ref'``
    (used under multi-device meshes where the flat view would break the
    parameter sharding; see launch/train.py).
    """
    if backend == "ref":
        w, _ = ref.reorth_ref(basis, w, mask)
        w, _ = ref.reorth_ref(basis, w, mask)
        return w
    interpret = _on_cpu()
    w, _ = reorth_pass(basis, w, mask, interpret=interpret)
    w, _ = reorth_pass(basis, w, mask, interpret=interpret)
    return w


def flat_gossip_update(w, remote, grads, momentum, partners, coefs, *,
                       lr: float, beta: float = 0.0, weight_decay: float = 0.0,
                       buffer=None, backend: str = "auto"):
    """Batched fused gossip+SGD update on the persistent (n, T, 128) store.

    The flat engine's hot-path dispatch (DESIGN §11): ``backend='pallas'``
    runs the learner-major Pallas kernel (Mosaic on TPU, interpret mode on
    CPU); ``backend='ref'`` the jnp oracle — same contract, the ground
    truth in tests.  ``'auto'`` (the default) picks the kernel on
    accelerators and the oracle on CPU: interpret mode exists to *verify*
    the kernel, not to win benchmarks, and the oracle is the faster correct
    implementation where there is no Mosaic compiler.

    momentum=None selects the momentum-free fused update (no (n, T, 128)
    momentum buffer is read or written).  ``buffer`` (AD-PSGD) switches on
    publish mode — see gossip_mix_update_flat; returns (w_new, mu_new,
    buffer_new) there, (w_new, mu_new) otherwise.
    """
    has_momentum = momentum is not None
    mu = momentum if has_momentum else w      # ignored when has_momentum=False
    if backend == "auto":
        backend = "ref" if _on_cpu() else "pallas"
    if backend == "ref":
        out = ref.gossip_mix_update_flat_ref(
            w, remote, grads, mu, partners, coefs, lr=lr, beta=beta,
            weight_decay=weight_decay, has_momentum=has_momentum,
            buffer=buffer)
    else:
        out = gossip_mix_update_flat(
            w, remote, grads, mu, partners, coefs, lr=lr, beta=beta,
            weight_decay=weight_decay, has_momentum=has_momentum,
            buffer=buffer, interpret=_on_cpu())
    w_new, mu_new = out[0], (out[1] if has_momentum else None)
    if buffer is not None:
        return w_new, mu_new, out[2]
    return w_new, mu_new


def flat_gossip_mix(w, partners, coefs, *, active=None,
                    backend: str = "auto"):
    """One mixing-only gossip round on the flat (n, T, 128) store.

    ``partners``: (K, n) int32; ``coefs``: (n, K + 1) f32 ``[self,
    neighbors...]`` — exactly one row of a compiled GossipSchedule
    (core/schedule.py).  Multi-round schedules (full-as-rounds,
    hierarchical, random matching with ``gossip_rounds > 1``) run their
    leading rounds through this and fuse the optimizer update into the
    LAST round only.  Reuses the batched kernel with a zero learning rate
    and ``w`` aliased as the (ignored) gradient operand, so arbitrary
    static K rides the same scalar-prefetch hot path with no second kernel
    to maintain.

    ``active`` ((n,) bool, elastic membership): inactive rows are left
    bitwise untouched by the kernel's in-pass select — a quarantined row
    holding arbitrary (even non-finite) values neither moves nor, given
    only-active partner tables, bleeds into live rows.
    """
    n = w.shape[0]
    act = (jnp.ones((n, 1), jnp.float32) if active is None
           else active.astype(jnp.float32)[:, None])
    pad = jnp.ones((n, 1), jnp.float32)          # lr scale (ignored: lr=0)
    full = jnp.concatenate([coefs.astype(jnp.float32), pad, act], axis=1)
    out = flat_gossip_update(w, w, w, None, partners, full, lr=0.0,
                             backend=backend)
    return out[0]


def dpsgd_fused_update(params_tree, neighbor_trees, grads_tree, momentum_tree,
                       coefs, *, lr: float, beta: float = 0.9):
    """Pytree-level fused gossip+momentum update (see kernels.gossip_mix).

    neighbor_trees: list of pytrees (the ppermute-received weight replicas).
    Returns (new_params_tree, new_momentum_tree).
    """
    w, unflatten_w = flatten_for_kernel(params_tree)
    mu, unflatten_mu = flatten_for_kernel(momentum_tree)
    g, _ = flatten_for_kernel(grads_tree)
    nbrs = jnp.stack([flatten_for_kernel(t)[0] for t in neighbor_trees])
    w_new, mu_new = gossip_mix_update(w, nbrs, g, mu,
                                      jnp.asarray(coefs, jnp.float32),
                                      lr=lr, beta=beta, interpret=_on_cpu())
    return unflatten_w(w_new), unflatten_mu(mu_new)
