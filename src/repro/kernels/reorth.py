"""Pallas TPU kernels: fused Lanczos reorthogonalization (dots + axpy).

Full reorthogonalization of a candidate Lanczos vector w against the m
basis vectors collected so far is the memory-bound inner loop of the
landscape probe (DESIGN §10):

    d_i = <v_i, w>                 i = 0..m-1     (masked to the live prefix)
    w  <- w - sum_i d_i v_i

Written naively (one jnp dot + one axpy per basis vector) XLA streams the
(T, 128) parameter view from HBM 2m times.  The two kernels here stream the
stacked basis V (M, T, 128) and w exactly once each:

  * ``reorth_dots``  — all M dot products in a single pass over {V, w},
    accumulating per-lane partial sums across the sequential TPU grid.
  * ``reorth_axpy``  — the M-term rank-1 subtraction in a single pass
    (same shape of fusion as kernels/gossip_mix.py's neighbor loop).

Traffic: 2(M+1) passes -> 2 passes + 2 over V, i.e. ~(2M+2)P vs (2M+3)P…
the win is per-*vector* reuse: w is read once per kernel instead of M
times, and the dot/axpy loop never materializes M temporaries.  Masking
(only the first j < M vectors are live at Lanczos step j) is applied to the
dot results, so one compiled kernel serves every iteration.

Like the other kernels, interpret mode (CPU container) measures correctness
cost; on TPU they compile to Mosaic.  ``kernels/ref.py`` holds the jnp
oracle (``reorth_ref``), pinned bitwise-close in tests/test_landscape.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
BLOCK_ROWS = 256          # (256, 128) f32 block = 128 KiB / buffer in VMEM


def _dots_kernel(v_ref, w_ref, out_ref, *, n_vecs: int):
    """Accumulate per-lane partial dots over the sequential row grid.

    v_ref: (M, rows, LANE); w_ref: (rows, LANE); out_ref: (M, LANE).
    """
    @pl.when(pl.program_id(0) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    w = w_ref[...].astype(jnp.float32)
    for k in range(n_vecs):
        out_ref[k, :] += jnp.sum(v_ref[k].astype(jnp.float32) * w, axis=0)


def _axpy_kernel(w_ref, v_ref, d_ref, out_ref, *, n_vecs: int):
    """out = w - sum_k d_k v_k on one (rows, LANE) tile; d in (M,) SMEM-like."""
    acc = w_ref[...].astype(jnp.float32)
    for k in range(n_vecs):
        acc -= d_ref[k] * v_ref[k].astype(jnp.float32)
    out_ref[...] = acc.astype(out_ref.dtype)


def _pad_rows(x, rows):
    """Zero-pad the row axis (axis -2) to a multiple of ``rows`` — zero rows
    contribute nothing to a dot and are sliced off after an axpy."""
    T = x.shape[-2]
    pad = (-T) % rows
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[-2] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("interpret", "block_rows"))
def reorth_dots(basis, w, *, interpret: bool = False,
                block_rows: int = BLOCK_ROWS):
    """All-M dot products <v_i, w> in one fused pass.

    basis: (M, T, 128) f32; w: (T, 128) f32.  Returns (M,) f32.
    """
    M, T, lane = basis.shape
    assert lane == LANE and w.shape == (T, LANE), (basis.shape, w.shape)
    rows = min(block_rows, T)
    basis, w = _pad_rows(basis, rows), _pad_rows(w, rows)
    T = w.shape[0]
    grid = (T // rows,)

    kern = functools.partial(_dots_kernel, n_vecs=M)
    lanes = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[pl.BlockSpec((M, rows, LANE), lambda i: (0, i, 0)),
                  pl.BlockSpec((rows, LANE), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((M, LANE), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((M, LANE), jnp.float32),
        interpret=interpret,
    )(basis, w)
    return jnp.sum(lanes, axis=1)


@functools.partial(jax.jit, static_argnames=("interpret", "block_rows"))
def reorth_axpy(w, basis, dots, *, interpret: bool = False,
                block_rows: int = BLOCK_ROWS):
    """w - sum_i dots_i v_i in one fused pass.

    w: (T, 128); basis: (M, T, 128); dots: (M,) f32.  Returns (T, 128).
    """
    M, T, lane = basis.shape
    assert lane == LANE and w.shape == (T, LANE), (basis.shape, w.shape)
    rows = min(block_rows, T)
    basis, w = _pad_rows(basis, rows), _pad_rows(w, rows)
    Tp = w.shape[0]
    grid = (Tp // rows,)

    kern = functools.partial(_axpy_kernel, n_vecs=M)
    block = pl.BlockSpec((rows, LANE), lambda i: (i, 0))
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[block,
                  pl.BlockSpec((M, rows, LANE), lambda i: (0, i, 0)),
                  pl.BlockSpec((M,), lambda i: (0,))],
        out_specs=block,
        out_shape=jax.ShapeDtypeStruct((Tp, LANE), w.dtype),
        interpret=interpret,
    )(w, basis, dots)
    return out[:T]


def reorth_pass(basis, w, mask, *, interpret: bool = False,
                block_rows: int = BLOCK_ROWS):
    """One classical-Gram-Schmidt sweep: w <- w - sum_{i: mask_i} <v_i,w> v_i.

    ``mask`` ((M,) 0/1 f32) selects the live prefix of the basis so the same
    compiled kernels serve every Lanczos iteration.  Returns (w_new, dots).
    """
    dots = reorth_dots(basis, w, interpret=interpret,
                       block_rows=block_rows) * mask
    return reorth_axpy(w, basis, dots, interpret=interpret,
                       block_rows=block_rows), dots
