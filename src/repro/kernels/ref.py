"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def gossip_mix_update_ref(w, neighbors, grads, momentum, coefs, *, lr: float,
                          beta: float = 0.9):
    """Same contract as kernels.gossip_mix.gossip_mix_update."""
    mixed = coefs[0] * w
    for k in range(neighbors.shape[0]):
        mixed = mixed + coefs[k + 1] * neighbors[k]
    mu_new = beta * momentum + grads
    return mixed - lr * mu_new, mu_new


def gossip_mix_update_flat_ref(w, remote, grads, momentum, partners, coefs, *,
                               lr: float, beta: float = 0.0,
                               weight_decay: float = 0.0,
                               has_momentum: bool = True, buffer=None):
    """Same contract as kernels.gossip_mix.gossip_mix_update_flat.

    Mirrors the kernel's arithmetic order (self term first, neighbors in
    schedule order, fused lr scale, where-based active select, publish-mode
    neighbor/buffer selects) so the two stay bitwise-close in interpret
    mode.  K is arbitrary: the loop consumes one compiled GossipSchedule
    round of any static neighbor count (padded self-loop slots contribute
    coefficient-0 terms, exactly like the kernel); with ``lr=0.0`` this is
    the mixing-only round ops.flat_gossip_mix dispatches."""
    K = partners.shape[0]
    publish = buffer is not None
    mixed = coefs[:, 0][:, None, None] * w
    for k in range(K):
        nbr = remote[partners[k]]
        if publish:
            nbr = jnp.where((coefs[:, 3 + K] > 0.5)[:, None, None], nbr,
                            buffer[partners[k]])
        mixed = mixed + coefs[:, 1 + k][:, None, None] * nbr
    g = grads
    if weight_decay:
        g = g + weight_decay * w
    lr_eff = (lr * coefs[:, 1 + K])[:, None, None]
    active = (coefs[:, 2 + K] > 0.5)[:, None, None]
    if has_momentum:
        mu_new = beta * momentum + g
        new_w = jnp.where(active, mixed - lr_eff * mu_new, w)
        mu_out = jnp.where(active, mu_new, momentum)
    else:
        new_w = jnp.where(active, mixed - lr_eff * g, w)
        mu_out = momentum
    if publish:
        buf_new = jnp.where((coefs[:, 4 + K] > 0.5)[:, None, None], new_w,
                            buffer)
        return new_w, mu_out, buf_new
    return new_w, mu_out


def reorth_ref(basis, w, mask):
    """Same contract as kernels.reorth.reorth_pass (one CGS sweep).

    basis: (M, T, 128); w: (T, 128); mask: (M,) 0/1.  Returns (w_new, dots).
    Loops vector-by-vector exactly like the kernel so the two stay
    bitwise-close in interpret mode.
    """
    wf = w.astype(jnp.float32)
    dots = jnp.stack([jnp.sum(basis[k].astype(jnp.float32) * wf)
                      for k in range(basis.shape[0])]) * mask
    acc = wf
    for k in range(basis.shape[0]):
        acc = acc - dots[k] * basis[k].astype(jnp.float32)
    return acc.astype(w.dtype), dots


def paged_decode_attention_ref(q, k_pages, v_pages, page_table, lengths, *,
                               window: int = 0, attn_softcap: float = 0.0):
    """Same contract as kernels.ops.paged_decode_attention.

    q: (S, H, hd) one query token per slot; k_pages, v_pages:
    (P, page, KV, hd) shared page pools; page_table: (S, max_pages) int32
    physical page ids in logical order; lengths: (S,) int32 valid tokens
    per slot (including the current one).  Gathers each slot's logical
    (W = max_pages * page) KV buffer through its table row, then runs the
    exact einsum/softmax chain of models.attention.attn_decode so the paged
    and rotating decode paths stay bitwise equal on CPU (the test pin).
    ``window`` keeps only the trailing ``window`` tokens (sliding-window
    layers); 0 disables it.  A slot with length 0 degenerates to a uniform
    softmax over the masked row — finite garbage the scheduler ignores.
    """
    S, H, hd = q.shape
    P, page, KV, _ = k_pages.shape
    G = H // KV
    W = page_table.shape[1] * page
    kc = k_pages[page_table].reshape(S, W, KV, hd)
    vc = v_pages[page_table].reshape(S, W, KV, hd)
    qg = q.reshape(S, KV, G, hd)
    s = jnp.einsum("bkgd,bwkd->bkgw", qg.astype(jnp.float32),
                   kc.astype(jnp.float32)) * hd ** -0.5
    if attn_softcap:
        s = attn_softcap * jnp.tanh(s / attn_softcap)
    kpos = jnp.arange(W)[None, :]
    valid = kpos < lengths[:, None]
    if window:
        valid &= kpos >= lengths[:, None] - window
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgw,bwkd->bkgd", p, vc.astype(jnp.float32))
    return o.reshape(S, H, hd).astype(q.dtype)


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                        attn_softcap: float = 0.0):
    """q: (B, H, Sq, hd); k, v: (B, KV, Sk, hd) -> (B, H, Sq, hd).
    Dense (unblocked) softmax attention with identical masking semantics."""
    B, H, Sq, hd = q.shape
    _, KV, Sk, _ = k.shape
    G = H // KV
    qf = q.astype(jnp.float32).reshape(B, KV, G, Sq, hd)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bkgqd,bksd->bkgqs", qf, kf) * hd ** -0.5
    if attn_softcap:
        s = attn_softcap * jnp.tanh(s / attn_softcap)
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= qpos >= kpos
    if window:
        mask &= qpos - kpos < window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bksd->bkgqd", p, vf)
    return o.reshape(B, H, Sq, hd).astype(q.dtype)
