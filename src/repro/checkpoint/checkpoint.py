"""Flat-npz pytree checkpointing with atomic writes and step indexing.

Layout:  <dir>/ckpt_<step>.npz   keys are '/'-joined pytree paths.
Restore requires a template pytree (for structure + dtypes) — standard for
pure-JAX frameworks; the trainer's init() provides it.
"""
from __future__ import annotations

import os
import re
import tempfile

import jax
import numpy as np


def _flatten(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
                       for p in path)
        out[key] = np.asarray(leaf)
    return out


def save_checkpoint(directory: str, step: int, tree) -> str:
    os.makedirs(directory, exist_ok=True)
    arrays = _flatten(tree)
    path = os.path.join(directory, f"ckpt_{step}.npz")
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".tmp")
    with os.fdopen(fd, "wb") as f:
        np.savez(f, **arrays)
    os.replace(tmp, path)  # atomic on POSIX
    return path


def latest_step(directory: str):
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for f in os.listdir(directory)
             if (m := re.fullmatch(r"ckpt_(\d+)\.npz", f))]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, template, step: int | None = None):
    """Returns (tree, step); raises FileNotFoundError if nothing saved."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    path = os.path.join(directory, f"ckpt_{step}.npz")
    with np.load(path) as data:
        flat = _flatten(template)
        missing = set(flat) - set(data.files)
        if missing:
            raise ValueError(f"checkpoint missing keys: {sorted(missing)[:5]}...")
        loaded = {k: data[k] for k in flat}
    leaves_tpl, treedef = jax.tree_util.tree_flatten_with_path(template)
    keys = ["/".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
                     for p in path_) for path_, _ in leaves_tpl]
    new_leaves = [jax.numpy.asarray(loaded[k], leaf.dtype)
                  for k, (_, leaf) in zip(keys, leaves_tpl)]
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template), new_leaves)
    return tree, step
