"""Flat-npz pytree checkpointing with crash-safe writes and step indexing.

Layout:  <dir>/ckpt_<step>.npz   keys are '/'-joined pytree paths.
Restore requires a template pytree (for structure + dtypes) — standard for
pure-JAX frameworks; the trainer's init() provides it.

Crash safety (DESIGN §15): a learner can die MID-WRITE, so a checkpoint
only becomes visible via an atomic rename of a fully-written, fsynced
temp file, and carries a content digest (sha256 over the sorted key/array
bytes, stored as the ``__digest__`` entry).  ``restore_checkpoint``
verifies the digest and, when asked for the latest step, transparently
falls back to the newest UNDAMAGED checkpoint — a truncated or
bit-flipped file is reported and skipped, never silently loaded.
"""
from __future__ import annotations

import hashlib
import os
import re
import tempfile

import jax
import numpy as np

DIGEST_KEY = "__digest__"


def _flatten(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
                       for p in path)
        out[key] = np.asarray(leaf)
    return out


def _digest(arrays: dict) -> str:
    h = hashlib.sha256()
    for key in sorted(arrays):
        if key == DIGEST_KEY:
            continue
        a = np.ascontiguousarray(arrays[key])
        h.update(key.encode())
        h.update(str(a.dtype).encode())
        h.update(str(a.shape).encode())
        h.update(a.tobytes())
    return h.hexdigest()


def save_checkpoint(directory: str, step: int, tree) -> str:
    os.makedirs(directory, exist_ok=True)
    arrays = _flatten(tree)
    arrays[DIGEST_KEY] = np.frombuffer(
        _digest(arrays).encode(), dtype=np.uint8)
    path = os.path.join(directory, f"ckpt_{step}.npz")
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, **arrays)
            f.flush()
            os.fsync(f.fileno())       # durable before it becomes visible
        os.replace(tmp, path)  # atomic on POSIX
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise
    return path


def _steps(directory: str):
    if not os.path.isdir(directory):
        return []
    return sorted((int(m.group(1)) for f in os.listdir(directory)
                   if (m := re.fullmatch(r"ckpt_(\d+)\.npz", f))))


def latest_step(directory: str):
    steps = _steps(directory)
    return max(steps) if steps else None


def verify_checkpoint(directory: str, step: int) -> bool:
    """True iff ``ckpt_<step>.npz`` exists, unzips, and its content digest
    matches — i.e. the file survived whatever killed its writer."""
    path = os.path.join(directory, f"ckpt_{step}.npz")
    try:
        with np.load(path) as data:
            if DIGEST_KEY not in data.files:
                return False            # pre-digest file or torn write
            want = bytes(data[DIGEST_KEY]).decode()
            arrays = {k: data[k] for k in data.files if k != DIGEST_KEY}
        return _digest(arrays) == want
    except Exception:
        return False


def restore_checkpoint(directory: str, template, step: int | None = None):
    """Returns (tree, step); raises FileNotFoundError if nothing loadable.

    ``step=None`` scans from the NEWEST step down, skipping corrupt or
    truncated files (a learner killed mid-write leaves at worst a stale
    ``.tmp``, but a torn pre-digest file from an older layout, or disk
    damage, must not poison the restore).  An explicit ``step`` is strict:
    corruption raises ``ValueError``.
    """
    if step is None:
        candidates = _steps(directory)
        if not candidates:
            raise FileNotFoundError(f"no checkpoints in {directory}")
        for s in reversed(candidates):
            if verify_checkpoint(directory, s):
                step = s
                break
        else:
            raise FileNotFoundError(
                f"no uncorrupted checkpoint in {directory} "
                f"(tried steps {candidates})")
    elif not verify_checkpoint(directory, step):
        raise ValueError(
            f"checkpoint ckpt_{step}.npz is corrupt or predates the "
            "digest format; refusing to load it explicitly")
    path = os.path.join(directory, f"ckpt_{step}.npz")
    with np.load(path) as data:
        flat = _flatten(template)
        missing = set(flat) - set(data.files)
        if missing:
            raise ValueError(f"checkpoint missing keys: {sorted(missing)[:5]}...")
        loaded = {k: data[k] for k in flat}
    leaves_tpl, treedef = jax.tree_util.tree_flatten_with_path(template)
    keys = ["/".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
                     for p in path_) for path_, _ in leaves_tpl]
    new_leaves = [jax.numpy.asarray(loaded[k], leaf.dtype)
                  for k, (_, leaf) in zip(keys, leaves_tpl)]
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template), new_leaves)
    return tree, step
