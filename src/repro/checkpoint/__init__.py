from .checkpoint import (save_checkpoint, restore_checkpoint, latest_step,
                         verify_checkpoint)

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "verify_checkpoint"]
