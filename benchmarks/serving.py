"""Serving benchmark: continuous batching vs static batching under an
open-loop Poisson arrival stream (ISSUE 7, DESIGN §14).

One cell = (engine mode, arrival rate).  The driver replays the SAME
deterministic arrival schedule (mixed-length prompts, mixed decode budgets,
exponential inter-arrival gaps in engine-step space) against a
:class:`repro.serve.ServeEngine` in ``continuous`` or ``static`` admission
mode and measures what a serving operator would: aggregate tokens/s,
us per model step, and request-completion latency percentiles (p50/p99).
Open-loop means arrivals do NOT wait for capacity — a saturated engine
grows its queue and the latency tail shows it, which is exactly the regime
where continuous batching's slot recycling wins over the static baseline's
head-of-line blocking.

``main`` additionally demonstrates the consensus-view bridge: a live flat
DPSGD trainer (n=4 learners, ring) keeps training the same tiny LM while a
snapshot of its consensus mean serves requests; the summary reports the
snapshot's staleness (steps behind, sigma_w then vs now) and the
logit-level divergence of the served snapshot against the current mean.

CLI (wired into ``make bench-smoke`` / the matrix ``serving`` workload):
    python -m benchmarks.serving [--smoke]
"""
from __future__ import annotations

import sys
import time

import numpy as np

# tiny dense LM sized so a CPU smoke run finishes in seconds; the serving
# metrics compare ENGINES, not models, so small is fine (and the cell key
# pins the model name so cross-PR trajectories stay aligned).
TINY = dict(name="tiny-lm", family="dense", n_layers=2, d_model=64,
            n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab=128,
            attn_chunk=16)

N_SLOTS = 4
PAGE_SIZE = 4
MAX_LEN = 16

_MODEL_CACHE: dict = {}


def _tiny_model():
    if "api" not in _MODEL_CACHE:
        import jax
        from repro.configs.base import ModelConfig
        from repro.models.model import build_model
        cfg = ModelConfig(**TINY)
        api = build_model(cfg)
        _MODEL_CACHE["api"] = api
        _MODEL_CACHE["params"] = api.init(jax.random.PRNGKey(0))
    return _MODEL_CACHE["api"], _MODEL_CACHE["params"]


def _arrival_schedule(rate: float, n_requests: int, seed: int = 0):
    """Deterministic open-loop schedule: (arrival_step, prompt, max_new)."""
    rng = np.random.default_rng(seed)
    t, sched = 0.0, []
    for _ in range(n_requests):
        t += rng.exponential(1.0 / rate)
        prompt = rng.integers(1, TINY["vocab"], rng.integers(1, 9)).tolist()
        max_new = int(rng.integers(2, min(8, MAX_LEN - len(prompt)) + 1))
        sched.append((t, prompt, max_new))
    return sched


def measure_cell(mode: str, rate: float, *, smoke: bool = False,
                 seed: int = 0) -> dict:
    """Run one (admission mode, arrival rate) serving cell -> metrics."""
    from repro.serve import ServeEngine

    api, params = _tiny_model()
    n_requests = 12 if smoke else 48
    sched = _arrival_schedule(rate, n_requests, seed)

    eng = ServeEngine(api, params, n_slots=N_SLOTS, page_size=PAGE_SIZE,
                      max_len=MAX_LEN, admission=mode)
    eng.warmup()

    pending = list(sched)
    inflight, t_submit, t_finish = [], {}, {}
    t0 = time.perf_counter()
    while pending or eng.has_work:
        while pending and pending[0][0] <= eng.step_count:
            _, prompt, max_new = pending.pop(0)
            r = eng.submit(prompt, max_new)
            t_submit[r.rid] = time.perf_counter()
            inflight.append(r)
        if eng.has_work:
            eng.step()
            now = time.perf_counter()
            for r in inflight:
                if r.done and r.rid not in t_finish:
                    t_finish[r.rid] = now
            inflight = [r for r in inflight if not r.done]
        else:
            eng.idle_tick()   # fast-forward to the next arrival
    wall = time.perf_counter() - t0

    lat_ms = np.array([(t_finish[rid] - t_submit[rid]) * 1e3
                       for rid in t_finish])
    assert len(lat_ms) == n_requests, "driver lost requests"
    return {
        "us_per_step": wall * 1e6 / max(eng.real_steps, 1),
        "tokens_per_s": eng.generated_total / wall,
        "p50_ms": float(np.percentile(lat_ms, 50)),
        "p99_ms": float(np.percentile(lat_ms, 99)),
        "n_requests": float(n_requests),
        "total_tokens": float(eng.generated_total),
        "real_steps": float(eng.real_steps),
        "stall_events": float(eng.stall_events),
    }


def bridge_demo(smoke: bool = False) -> dict:
    """Serve consensus snapshots of a LIVE flat DPSGD trainer; report
    staleness and served-output divergence (the ISSUE 7 bridge contract)."""
    import jax
    import jax.numpy as jnp
    from repro.core import AlgoConfig, MultiLearnerTrainer
    from repro.models.model import make_synthetic_batch
    from repro.optim import sgd
    from repro.serve import ConsensusBridge, ServeEngine, served_divergence

    api, params = _tiny_model()
    n = 4
    tr = MultiLearnerTrainer(
        api.loss_fn, sgd(0.05),
        AlgoConfig(algo="dpsgd", topology="ring", n_learners=n),
        engine="flat")
    key = jax.random.PRNGKey(0)
    st = tr.init(key, params)

    def batch(i):
        b = make_synthetic_batch(api.cfg, jax.random.PRNGKey(i), n * 2, 16)
        return jax.tree_util.tree_map(
            lambda x: x.reshape((n, 2) + x.shape[1:]), b)

    warm, extra = (2, 3) if smoke else (5, 10)
    for i in range(warm):
        st, _ = tr.train_step(st, batch(i))

    bridge = ConsensusBridge(tr)
    snap = bridge.snapshot(st)
    eng = ServeEngine(api, snap.params, n_slots=N_SLOTS,
                      page_size=PAGE_SIZE, max_len=MAX_LEN)
    served = []
    for _, prompt, max_new in _arrival_schedule(1.0, 3, seed=7):
        served.append(eng.submit(prompt, max_new))
    # training keeps moving WHILE the snapshot serves: interleave
    for i in range(extra):
        st, _ = tr.train_step(st, batch(warm + i))
        if eng.has_work:
            eng.step()
    eng.run()
    assert all(r.done for r in served)

    stale = bridge.staleness(st, snap)
    live = bridge.snapshot(st)
    probe = jnp.asarray(
        np.random.default_rng(3).integers(1, api.cfg.vocab, (2, 8)))
    div = served_divergence(api, snap.params, live.params, probe)
    eng.set_params(live.params)   # hot swap: same shapes, no retrace
    return {**stale, **div,
            "served_tokens": sum(len(r.generated) for r in served)}


def main(argv=None) -> int:
    from .common import fmt, parse_smoke, write_table

    smoke = parse_smoke(argv)
    t0 = time.perf_counter()
    rows, cells = [], {}
    for mode in ("continuous", "static"):
        for rate in (0.25, 1.0):
            m = measure_cell(mode, rate, smoke=smoke)
            cells[(mode, rate)] = m
            rows.append([mode, rate, fmt(m["us_per_step"]),
                         fmt(m["tokens_per_s"]), fmt(m["p50_ms"]),
                         fmt(m["p99_ms"]), int(m["total_tokens"]),
                         int(m["real_steps"]), int(m["stall_events"])])
    write_table("bench_serving",
                ["mode", "rate", "us_per_step", "tokens_per_s", "p50_ms",
                 "p99_ms", "total_tokens", "real_steps", "stall_events"],
                rows)

    # the tentpole claim: under the heavy mixed-length stream, continuous
    # batching's slot recycling beats static admission on aggregate tokens/s
    cont, stat = cells[("continuous", 1.0)], cells[("static", 1.0)]
    speedup = cont["tokens_per_s"] / stat["tokens_per_s"]
    assert speedup > 1.0, (
        f"continuous {cont['tokens_per_s']:.1f} tok/s did not beat "
        f"static {stat['tokens_per_s']:.1f} tok/s at rate=1.0")

    bd = bridge_demo(smoke)
    us = (time.perf_counter() - t0) * 1e6 / max(len(rows), 1)
    derived = (f"continuous/static tok/s x{speedup:.2f} at rate=1.0; "
               f"bridge steps_behind={bd['steps_behind']} "
               f"top1_agree={bd['top1_agreement']:.2f} "
               f"sigma_w {bd['consensus_dist_snapshot']:.3g}->"
               f"{bd['consensus_dist_now']:.3g}")
    print(f"bench_serving,{us:.0f},{derived}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
