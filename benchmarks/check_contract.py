"""CSV-contract check for the benchmark suite (benchmarks/README.md).

Validates that a captured benchmark run (e.g. ``make bench-smoke | tee out``)
honors the output contract:

  * every summary line that claims to be a benchmark row parses as
    ``name,us_per_call,derived`` with at most 2 splits (derived is free
    text and may itself contain commas),
  * every required benchmark (argv[2:], prefix-matched) produced >= 1 row,
  * every results/bench/ table belonging to a required benchmark is a
    non-empty CSV with a header row (with no required names given, ALL
    tables are checked — the full `benchmarks.run` sweep mode).

Usage:
    python -m benchmarks.check_contract <captured-stdout> [required-name...]

Exits non-zero with a per-violation report; CI uploads results/bench as an
artifact right after this gate.
"""
from __future__ import annotations

import csv
import os
import re
import sys

from .schema import results_dir

# a contract row: bare name, numeric us_per_call, non-empty derived text
ROW_RE = re.compile(r"^([a-z0-9_]+),([0-9]+(?:\.[0-9]+)?),(.+)$")


def parse_rows(text: str):
    rows = []
    for line in text.splitlines():
        m = ROW_RE.match(line.strip())
        if m:
            rows.append((m.group(1), float(m.group(2)), m.group(3)))
    return rows


def check_tables(results_dir: str, required=()):
    errors = []
    if not os.path.isdir(results_dir):
        return [f"missing results dir {results_dir}"]
    stems = [f[:-4] for f in os.listdir(results_dir) if f.endswith(".csv")]
    # a required benchmark must have written SOME results table at all
    for need in required:
        if not any(s.startswith(need) or need.startswith(s) for s in stems):
            errors.append(f"required benchmark `{need}` wrote no results "
                          f"table under {results_dir}")
    for fname in sorted(os.listdir(results_dir)):
        if not fname.endswith(".csv"):
            continue
        stem = fname[:-4]
        # smoke runs only vouch for their own tables; stale tables from
        # other benchmarks (e.g. an old roofline aggregate) are not theirs
        if required and not any(stem.startswith(r) or r.startswith(stem)
                                for r in required):
            continue
        path = os.path.join(results_dir, fname)
        with open(path, newline="") as f:
            table = list(csv.reader(f))
        if not table:
            errors.append(f"{fname}: empty table")
        elif len(table[0]) < 2:
            errors.append(f"{fname}: header has < 2 columns: {table[0]}")
        elif len(table) < 2:
            errors.append(f"{fname}: header but no data rows")
    return errors


def main(argv=None) -> int:
    argv = argv if argv is not None else sys.argv[1:]
    if not argv:
        print("usage: check_contract <captured-stdout> [required-name...]")
        return 2
    with open(argv[0]) as f:
        text = f.read()
    required = argv[1:]

    rows = parse_rows(text)
    errors = []
    if not rows:
        errors.append("no `name,us_per_call,derived` rows found in output")
    for need in required:
        if not any(name.startswith(need) for name, _, _ in rows):
            errors.append(f"required benchmark `{need}` emitted no row")
    errors += check_tables(os.path.abspath(results_dir()), required)

    for name, us, derived in rows:
        print(f"ok: {name} ({us:.0f} us) {derived[:60]}")
    for e in errors:
        print(f"CONTRACT VIOLATION: {e}", file=sys.stderr)
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
