"""Engine regression harness (App. F + DESIGN §11).

Measures the REAL research-trainer hot path per algorithm, old vs new:

  * pytree — the reference engine: stacked pytrees, unfused tree_map
    updates, one host dispatch per step (how the repo trained before PR 3);
  * flat   — the flat-state engine: persistent (n, T, 128) store, batched
    fused gossip kernel, ``run_steps`` lax.scan driver with state donation.

Emits ``results/bench/BENCH_PR3.json`` with us/step and tokens/s per
(algo, engine) plus the traced-step concatenate audit, and the usual CSV
table.  ``make bench-check`` gates on it via benchmarks.check_regression:
the flat engine must not regress past the pytree path beyond the measured
CPU parity-noise band on this smoke config, the fused kernel must actually
dispatch, and the traced step must stay free of parameter-sized
concatenates.  The derived production collective volume per gossip backend
(roofline model, App. F) is carried along in the JSON for context.

``measure_cell`` is the single-engine unit benchmarks.matrix reuses as
its ``throughput`` workload plugin; the emitted BENCH_PR3.json is the v1
payload the schema's legacy adapter keeps aligned with matrix cells
(DESIGN §13).  ``--smoke`` shortens the paired run.
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import AlgoConfig, MultiLearnerTrainer
from repro.core.flatstate import max_concat_elems
from repro.data import ShardedLoader, TemplateImages
from repro.launch.analytic import gossip_link_bytes_per_chip
from repro.models import fcnet
from repro.optim import sgd

from .common import parse_smoke, write_table
from .schema import results_dir

# smoke config: the paper's FC net / learner count at CPU scale.
# CHUNK x CHUNKS steps per engine, interleaved chunkwise (below).
N, LOCAL_BATCH, LR, CHUNK, CHUNKS = 5, 400, 0.1, 6, 16
STEPS = CHUNK * CHUNKS
ALGOS = ("ssgd", "dpsgd", "adpsgd")
ALGO_KW = {"adpsgd": dict(max_staleness=4, slow_learner=0, slow_factor=3)}


def _make(algo: str, engine: str) -> MultiLearnerTrainer:
    return MultiLearnerTrainer(
        fcnet.loss_fn, sgd(LR, momentum=0.9),
        AlgoConfig(algo=algo, topology="random_pair", n_learners=N,
                   **ALGO_KW.get(algo, {})),
        engine=engine)


def _workload_inputs(chunk: int):
    loader = ShardedLoader(TemplateImages(), n_learners=N,
                           local_batch=LOCAL_BATCH, seed=0)
    params = fcnet.init_params(jax.random.PRNGKey(0), in_dim=784, hidden=50)
    batches = [loader.batch(i) for i in range(chunk)]
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *batches)
    return params, batches, stacked


def measure_cell(algo: str, engine: str, *, chunk: int = CHUNK,
                 chunks: int = 4):
    """Single-engine measurement for one matrix cell (benchmarks.matrix).

    Same drivers as the paired harness below — per-step loop for the
    pytree engine, the ``run_steps`` scan for flat — so matrix cells stay
    comparable with the legacy BENCH_PR3.json cells the trajectory aligns
    them against.  Returns (metrics, extra) in the schema-v2 cell shape.
    """
    params, batches, stacked = _workload_inputs(chunk)
    tr = _make(algo, engine)
    st = tr.init(jax.random.PRNGKey(0), params)
    flat = tr._flat

    def run_chunk(st):
        if flat:
            st, _ = tr.run_steps(st, stacked, k=chunk)
        else:
            for b in batches:
                st, _ = tr.train_step(st, b)
        return st

    st = run_chunk(st)                                 # compile + warm
    jax.block_until_ready(st.params)
    t0 = time.perf_counter()
    for _ in range(chunks):
        st = run_chunk(st)
    jax.block_until_ready(st.params)
    s = (time.perf_counter() - t0) / (chunk * chunks)
    metrics = {"us_per_step": s * 1e6,
               "tokens_per_s": N * LOCAL_BATCH / s}
    extra = {"source": "bench_throughput",
             "fused_kernel": tr._fused is not None, "flat_engine": flat}
    return metrics, extra


def _measure(algo: str, params, batches, stacked, chunks=CHUNKS):
    """Finely paired engine timing, robust to machine-load drift.

    Both engines train continuously (donated states, real drivers: per-step
    loop for pytree — the pre-PR3 hot path — and the run_steps scan for
    flat), alternating every CHUNK steps so the two accumulate wall time
    under near-identical machine load; run-level pairing (hundreds of ms
    apart) measurably does NOT cancel load swings on shared hosts.  One
    warm-up chunk per engine (compile) is excluded."""
    tr_tree = _make(algo, "pytree")
    tr_flat = _make(algo, "flat")
    st_tree = tr_tree.init(jax.random.PRNGKey(0), params)
    st_flat = tr_flat.init(jax.random.PRNGKey(0), params)
    for b in batches:                                  # compile + warm
        st_tree, _ = tr_tree.train_step(st_tree, b)
    st_flat, _ = tr_flat.run_steps(st_flat, stacked, k=CHUNK)
    t_tree = t_flat = 0.0
    for _ in range(chunks):
        t0 = time.perf_counter()
        for b in batches:
            st_tree, _ = tr_tree.train_step(st_tree, b)
        jax.block_until_ready(st_tree.params)
        t_tree += time.perf_counter() - t0
        t0 = time.perf_counter()
        st_flat, _ = tr_flat.run_steps(st_flat, stacked, k=CHUNK)
        jax.block_until_ready(st_flat.params)
        t_flat += time.perf_counter() - t0
    steps = CHUNK * chunks
    return tr_flat, t_tree / steps, t_flat / steps, t_flat / t_tree


def main(argv=None):
    smoke = parse_smoke(argv)
    chunks = 4 if smoke else CHUNKS
    params, batches, stacked = _workload_inputs(CHUNK)
    tokens_per_step = N * LOCAL_BATCH       # 1 sample == 1 token (FC proxy)

    rows, report = [], {}
    for algo in ALGOS:
        tr_flat, s_tree, s_flat, ratio = _measure(algo, params, batches,
                                                  stacked, chunks)
        # audit: the traced flat step must not concatenate anything
        # parameter-sized (the per-step re-flatten this PR removed)
        st = tr_flat.init(jax.random.PRNGKey(0), params)
        concat = max_concat_elems(jax.make_jaxpr(tr_flat._train_step)(
            st, batches[0]))
        report[algo] = {
            "pytree_us_per_step": s_tree * 1e6,
            "flat_us_per_step": s_flat * 1e6,
            "flat_speedup": 1.0 / ratio,
            "flat_over_pytree_ratio": ratio,
            "tokens_per_s_pytree": tokens_per_step / s_tree,
            "tokens_per_s_flat": tokens_per_step / s_flat,
            "flat_step_max_concat_elems": concat,
            "fused_kernel": tr_flat._fused is not None,
            "default_engine_flat": MultiLearnerTrainer(
                fcnet.loss_fn, sgd(LR),
                AlgoConfig(algo=algo, topology="random_pair",
                           n_learners=N, **ALGO_KW.get(algo, {})))._flat,
        }
        rows.append([algo, s_tree * 1e6, s_flat * 1e6, 1.0 / ratio,
                     tokens_per_step / s_flat])

    cfg = get_config("yi-34b")
    volume = {
        "yi34b_gossip_einsum_GB":
            gossip_link_bytes_per_chip(cfg, 256, 16, "einsum") / 1e9,
        "yi34b_gossip_ppermute_GB":
            gossip_link_bytes_per_chip(cfg, 256, 16, "ppermute") / 1e9,
    }
    payload = {
        "config": {"n_learners": N, "local_batch": LOCAL_BATCH, "lr": LR,
                   "steps": CHUNK * chunks, "chunk": CHUNK,
                   "model": "fcnet-784-50-50-10",
                   "n_elem": int(tr_flat._meta.n_elem)},
        "algos": report,
        "gossip_volume": volume,
    }
    os.makedirs(results_dir(), exist_ok=True)
    with open(os.path.join(results_dir(), "BENCH_PR3.json"), "w") as f:
        json.dump(payload, f, indent=2)

    write_table("bench_throughput",
                ["algo", "pytree_us_per_step", "flat_us_per_step",
                 "flat_speedup", "flat_tokens_per_s"], rows)
    d = report["dpsgd"]
    derived = ("flat/pytree speedup: "
               + " ".join(f"{a}={report[a]['flat_speedup']:.2f}x"
                          for a in ALGOS)
               + f"; dpsgd flat {d['tokens_per_s_flat']:.0f} tok/s, "
               f"step concat={d['flat_step_max_concat_elems']} elems "
               "(BENCH_PR3.json gated by check_regression)")
    print(f"bench_throughput,{d['flat_us_per_step']:.0f},{derived}")


if __name__ == "__main__":
    main()
