"""App. F end-to-end runtime: measured CPU step time of the research trainer
(SSGD vs DPSGD) plus the derived production collective volume per step from
the roofline model for each gossip backend."""
from __future__ import annotations

from repro.configs import get_config
from repro.launch.analytic import gossip_link_bytes_per_chip

from .common import train_fc, write_table


def main():
    rows = []
    us = {}
    for algo in ("ssgd", "dpsgd"):
        r = train_fc(algo, 0.25, steps=40)
        us[algo] = r["us_per_step"]
        rows.append([algo, r["us_per_step"]])
    cfg = get_config("yi-34b")
    eins = gossip_link_bytes_per_chip(cfg, 256, 16, "einsum")
    pp = gossip_link_bytes_per_chip(cfg, 256, 16, "ppermute")
    rows.append(["yi34b_gossip_einsum_GB", eins / 1e9])
    rows.append(["yi34b_gossip_ppermute_GB", pp / 1e9])
    write_table("bench_throughput", ["metric", "value"], rows)
    derived = (f"dpsgd/ssgd step ratio={us['dpsgd'] / us['ssgd']:.2f}; "
               f"gossip einsum={eins / 1e9:.1f}GB ppermute={pp / 1e9:.1f}GB "
               f"per chip (paper AppF: DPSGD cheaper comms)")
    print(f"bench_throughput,{us['dpsgd']:.0f},{derived}")


if __name__ == "__main__":
    main()
