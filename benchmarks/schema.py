"""Schema for the cross-PR benchmark-matrix artifacts (DESIGN §13).

Every PR's benchmark run emits one ``BENCH_PR<N>.json``.  This module owns
the record format those files share, so `benchmarks.trajectory` can align
cells across PRs and `benchmarks.check_regression` can gate on them:

  * ``SCHEMA_VERSION = 2`` payloads are what `benchmarks.matrix` emits:
    ``{"schema_version": 2, "pr": N, "config": {...}, "cells": {key: cell}}``
    where each cell is ``{"axes": {...}, "metrics": {...}, "extra": {...},
    "tolerance": <optional per-cell gate band>}``.
  * the **cell key** is the stable cross-PR identity: the canonical axes
    (``AXES`` below, in that order) plus any workload-specific extra axes
    sorted by name, serialized ``k=v`` and joined with ``/``.  Two PRs that
    measure the same cell MUST produce the same key — that contract is what
    makes the trajectory report meaningful (and is pinned by tests).
  * version-1 payloads (the pre-matrix ``BENCH_PR3.json`` written by
    `benchmarks.bench_throughput`, no ``schema_version`` field) are adapted
    on load into v2 cells — one per (algo, engine) — so the trajectory
    never orphans pre-matrix history.

This module is deliberately free of jax / repro imports: schema validation
and trajectory math must stay importable (and unit-testable) without
pulling in the training stack.
"""
from __future__ import annotations

import json
import os
import re

SCHEMA_VERSION = 2

# canonical sweep axes, in cell-key order (ISSUE 6 / ROADMAP item 5)
AXES = ("workload", "model", "algo", "topology", "n", "precision", "engine")

_PR_RE = re.compile(r"BENCH_PR(\d+)\.json$")

# where benchmark artifacts land; REPRO_BENCH_RESULTS overrides (tests)
_DEFAULT_RESULTS = os.path.join(os.path.dirname(__file__), "..", "results",
                                "bench")
# committed cross-PR history (real BENCH_PR<N>.json snapshots; the legacy
# BENCH_PR3.json lives here so the backward-compat adapter has a real file)
HISTORY = os.path.join(os.path.dirname(__file__), "history")


def results_dir() -> str:
    return os.environ.get("REPRO_BENCH_RESULTS") or _DEFAULT_RESULTS


class SchemaError(ValueError):
    """A BENCH_*.json payload that violates the schema contract."""


def cell_key(axes: dict) -> str:
    """Stable cell identity: canonical axes first, extra axes sorted."""
    missing = [k for k in AXES if k not in axes]
    if missing:
        raise SchemaError(f"cell axes missing {missing} (have {sorted(axes)})")
    extra = sorted(k for k in axes if k not in AXES)
    return "/".join(f"{k}={axes[k]}" for k in (*AXES, *extra))


def make_cell(axes: dict, metrics: dict, extra: dict | None = None,
              tolerance: float | None = None) -> tuple[str, dict]:
    """Build one validated (key, cell-record) pair."""
    cell = {"axes": dict(axes), "metrics": dict(metrics)}
    if extra:
        cell["extra"] = dict(extra)
    if tolerance is not None:
        cell["tolerance"] = float(tolerance)
    return cell_key(axes), cell


def new_payload(pr: int, config: dict | None = None) -> dict:
    return {"schema_version": SCHEMA_VERSION, "pr": int(pr),
            "config": dict(config or {}), "cells": {}}


def validate(payload: dict) -> list[str]:
    """Returns a list of contract violations (empty == valid v2 payload)."""
    errors = []
    ver = payload.get("schema_version")
    if ver != SCHEMA_VERSION:
        return [f"unknown schema_version {ver!r} (this loader speaks "
                f"{SCHEMA_VERSION}; v1 files are adapted by load_result)"]
    if not isinstance(payload.get("pr"), int):
        errors.append(f"missing/non-int pr field: {payload.get('pr')!r}")
    cells = payload.get("cells")
    if not isinstance(cells, dict) or not cells:
        return errors + ["cells must be a non-empty dict keyed by cell key"]
    for key, cell in cells.items():
        axes = cell.get("axes")
        if not isinstance(axes, dict):
            errors.append(f"{key}: missing axes dict")
            continue
        try:
            expect = cell_key(axes)
        except SchemaError as e:
            errors.append(f"{key}: {e}")
            continue
        if expect != key:
            errors.append(f"cell key {key!r} does not match its axes "
                          f"(expected {expect!r})")
        metrics = cell.get("metrics")
        if not isinstance(metrics, dict) or not metrics:
            errors.append(f"{key}: missing/empty metrics dict")
        elif not all(isinstance(v, (int, float)) and not isinstance(v, bool)
                     for v in metrics.values()):
            errors.append(f"{key}: non-numeric metric values: {metrics}")
    return errors


def pr_from_filename(path: str) -> int | None:
    m = _PR_RE.search(os.path.basename(path))
    return int(m.group(1)) if m else None


def _adapt_legacy(payload: dict, path: str) -> dict:
    """v1 (`bench_throughput`) -> v2: one cell per (algo, engine).

    The axes mirror what `benchmarks.matrix` emits for the same
    measurement (workload=throughput, model=fcnet, topology=random_pair),
    so legacy history aligns with matrix cells by key.
    """
    cfg = payload.get("config", {})
    pr = pr_from_filename(path)
    if pr is None:
        raise SchemaError(f"{path}: legacy payload needs a BENCH_PR<N>.json "
                          "filename to recover its PR number")
    out = new_payload(pr, cfg)
    out["legacy"] = True
    algos = payload.get("algos")
    if not isinstance(algos, dict) or not algos:
        raise SchemaError(f"{path}: legacy payload has no algos table")
    for algo, r in algos.items():
        for engine in ("pytree", "flat"):
            try:
                metrics = {
                    "us_per_step": float(r[f"{engine}_us_per_step"]),
                    "tokens_per_s": float(r[f"tokens_per_s_{engine}"]),
                }
            except KeyError as e:
                raise SchemaError(
                    f"{path}: legacy algo {algo!r} missing field {e}")
            extra = {"source": "bench_throughput"}
            if engine == "flat":
                extra.update(
                    fused_kernel=bool(r.get("fused_kernel")),
                    flat_step_max_concat_elems=r.get(
                        "flat_step_max_concat_elems"),
                    flat_over_pytree_ratio=r.get("flat_over_pytree_ratio"))
            key, cell = make_cell(
                {"workload": "throughput", "model": "fcnet", "algo": algo,
                 "topology": "random_pair",
                 "n": int(cfg.get("n_learners", 0)), "precision": "f32",
                 "engine": engine},
                metrics, extra=extra)
            out["cells"][key] = cell
    return out


def load_result(path: str) -> dict:
    """Load + validate one BENCH_*.json, adapting v1 payloads to v2.

    Raises SchemaError on any contract violation (including unknown
    versions), FileNotFoundError if the file is absent.
    """
    with open(path) as f:
        payload = json.load(f)
    if "schema_version" not in payload and "algos" in payload:
        payload = _adapt_legacy(payload, path)
    errors = validate(payload)
    if errors:
        raise SchemaError(f"{path}: " + "; ".join(errors))
    payload.setdefault("source_path", path)
    return payload
