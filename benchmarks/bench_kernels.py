"""Kernel microbench: wall time of the pure-jnp oracle vs the Pallas kernel
in interpret mode (CPU container — interpret mode measures CORRECTNESS cost,
not TPU speed), plus the derived HBM-traffic model ratio that motivates the
fusion (DESIGN.md §7)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import (flat_gossip_update, gossip_mix_update, ref,
                           reorth_pass)
from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.ops import dpsgd_fused_update

from .common import parse_smoke, write_table


def timeit(fn, *args, reps=3):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def main(argv=None):
    smoke = parse_smoke(argv)
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 4)
    rows = []

    T, K = (1024 if smoke else 4096), 2
    w = jax.random.normal(ks[0], (T, 128))
    nb = jax.random.normal(ks[1], (K, T, 128))
    g = jax.random.normal(ks[2], (T, 128))
    mu = jax.random.normal(ks[3], (T, 128))
    coefs = jnp.array([0.5, 0.25, 0.25])
    us_ref = timeit(lambda *a: ref.gossip_mix_update_ref(
        *a, lr=0.1, beta=0.9)[0], w, nb, g, mu, coefs)
    us_int = timeit(lambda *a: gossip_mix_update(
        *a, lr=0.1, beta=0.9, interpret=True)[0], w, nb, g, mu, coefs)
    # HBM traffic model: unfused 3 passes (mix, momentum, apply) vs fused 1
    unfused = (1 + K + 1) * 4 + (1 + 1) * 4 + (2 + 1) * 4   # per elem bytes
    fused = (1 + K + 1 + 1) * 4 + 2 * 4
    rows.append(["gossip_mix", us_ref, us_int, unfused / fused])

    # end-to-end engine step: the per-call flatten wrapper (re-flattens every
    # pytree on every call — the pre-PR3 hot-path overhead) vs the flat
    # engine's persistent (n, T, 128) store feeding the batched update
    # directly (DESIGN §11).  Timing this end to end keeps the removed
    # flatten regression visible if it ever sneaks back.
    n = 4
    tree = {"w1": jax.random.normal(ks[0], (512, 96)),
            "b1": jnp.ones((96,)),
            "w2": jax.random.normal(ks[1], (96, 48))}
    nbr = jax.tree_util.tree_map(lambda x: x + 1.0, tree)
    gt = jax.tree_util.tree_map(jnp.ones_like, tree)
    mt = jax.tree_util.tree_map(jnp.zeros_like, tree)
    us_wrap = timeit(lambda *a: dpsgd_fused_update(
        *a, [0.5, 0.5], lr=0.1, beta=0.9)[0]["w1"], tree, [nbr], gt, mt)
    from repro.core.flatstate import flat_meta
    meta = flat_meta(tree)
    Tn = meta.rows
    wf = jax.random.normal(ks[2], (n, Tn, 128))
    gf = jnp.ones((n, Tn, 128))
    mf = jnp.zeros((n, Tn, 128))
    partners = jnp.array([[1, 0, 3, 2]], jnp.int32)
    coefs = jnp.tile(jnp.array([0.5, 0.5, 1.0, 1.0], jnp.float32), (n, 1))
    flat_step = jax.jit(lambda w, g, mu: flat_gossip_update(
        w, w, g, mu, partners, coefs, lr=0.1, beta=0.9, backend="pallas")[0])
    us_flat = timeit(flat_step, wf, gf, mf)
    # traffic model, K=1: wrapper re-flattens {w, nbr, g, mu} (2 passes
    # each) + kernel (4r+2w) + unflattens {w, mu} (2 passes each) vs the
    # persistent store's bare kernel passes
    rows.append(["gossip_mix_e2e", us_wrap, us_flat / n,
                 (2 * 4 + 6 + 2 * 2) / 6])

    # Lanczos full-reorth sweep (landscape probe inner loop, DESIGN §10):
    # fused dots+axpy streams {V, w} once per pass vs once per basis vector
    M = 4 if smoke else 8
    V = jax.random.normal(ks[0], (M, T, 128))
    wv = jax.random.normal(ks[1], (T, 128))
    mask = jnp.ones((M,), jnp.float32)
    us_ref3 = timeit(lambda *a: ref.reorth_ref(*a)[0], V, wv, mask)
    us_int3 = timeit(lambda *a: reorth_pass(*a, interpret=True)[0],
                     V, wv, mask)
    # traffic model: unfused 2M passes over w + 2 over V vs fused 2 + 2
    rows.append(["reorth", us_ref3, us_int3, (2 * M + 2) / 4])

    S, hd = (256 if smoke else 512), 64
    q = jax.random.normal(ks[0], (1, 4, S, hd))
    k = jax.random.normal(ks[1], (1, 2, S, hd))
    v = jax.random.normal(ks[2], (1, 2, S, hd))
    us_ref2 = timeit(lambda *a: ref.flash_attention_ref(*a, causal=True),
                     q, k, v)
    us_int2 = timeit(lambda *a: flash_attention_fwd(
        *a, causal=True, block_q=128, block_k=128, interpret=True), q, k, v)
    # derived: causal tile skipping -> ~2x fewer score flops + no S^2 matrix
    rows.append(["flash_attention", us_ref2, us_int2, 2.0])

    write_table("bench_kernels",
                ["kernel", "ref_us", "interpret_us", "derived_traffic_ratio"],
                rows)
    for name, us_ref_, us_int_, ratio in rows:
        print(f"bench_kernel_{name},{us_ref_:.0f},"
              f"traffic_ratio={ratio:.2f} interpret_us={us_int_:.0f}")


if __name__ == "__main__":
    main()
