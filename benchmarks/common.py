"""Shared harness for the paper-table benchmarks (CPU scale).

Every benchmark prints CSV rows `name,us_per_call,derived` (run.py contract)
and writes its full table to results/bench/<name>.csv.  Every workload's
``main(argv)`` honors ``--smoke`` (parse_smoke): shorter training / trimmed
sweeps, same tables and summary row — that mode is what `make bench-check`
and the tests/test_bench_smoke.py sweep exercise.
"""
from __future__ import annotations

import csv
import math
import os
import sys
import time

import jax

from repro.core import AlgoConfig, MultiLearnerTrainer
from repro.data import ShardedLoader, TemplateImages
from repro.landscape import (AutoLRController, ProbeSchedule,
                             make_trainer_probe)
from repro.models import fcnet
from repro.optim import scale_by_controller, set_controller_scale, sgd

from .schema import results_dir

RESULTS = results_dir()   # back-compat alias; prefer results_dir()


def parse_smoke(argv) -> bool:
    """The shared workload CLI: ``--smoke`` means short-but-complete."""
    argv = sys.argv[1:] if argv is None else list(argv)
    return "--smoke" in argv


def write_table(name: str, header, rows):
    os.makedirs(results_dir(), exist_ok=True)
    path = os.path.join(results_dir(), f"{name}.csv")
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(header)
        w.writerows(rows)
    return path


def train_fc(algo: str, lr: float, *, n: int = 5, local_batch: int = 400,
             steps: int = 150, seed: int = 0, noise_std: float = 0.01,
             topology: str = "random_pair", diag_every: int = 0,
             landscape_every: int = 0, autolr=None, probe_kwargs=None,
             dataset=None, optimizer=None, algo_kwargs=None,
             engine: str = "auto", fault_plan=None):
    """Returns dict(losses, diags, probes, us_per_step, trainer, state, loader).

    ``algo_kwargs`` are forwarded to AlgoConfig (adpsgd staleness bound /
    straggler injection: max_staleness, slow_learner, slow_factor);
    ``engine`` selects the trainer engine (DESIGN §11) — the matrix
    harness sweeps it as a first-class axis.  ``fault_plan`` (a
    ``repro.core.FaultPlan``) runs the training loop under a
    :class:`~repro.core.Supervisor`: elastic membership, scripted
    crash/rejoin/slow/drop faults, wedge detection — the seeded
    injection path shared with the fault tests (DESIGN §15).

    Probes ride the trainer's hook seam (DESIGN §10): ``diag_every`` runs
    the paper diagnostics, ``landscape_every`` the curvature probe; results
    land in ``diags`` / ``probes`` as (step, result) pairs.  ``algo=
    'ssgd_autolr'`` runs SSGD with the optimizer wrapped in
    scale_by_controller and an AutoLRController closing the loop at
    ``landscape_every`` cadence (default every 10 steps).
    """
    ds = dataset or TemplateImages()
    loader = ShardedLoader(ds, n_learners=n, local_batch=local_batch,
                           seed=seed)
    key = jax.random.PRNGKey(seed)
    params = fcnet.init_params(key, in_dim=784, hidden=50)

    controller = None
    opt = optimizer or sgd(lr)
    if algo == "ssgd_autolr":
        algo = "ssgd"
        opt = scale_by_controller(opt)
        controller = autolr or AutoLRController(alpha0=lr)
        landscape_every = landscape_every or 10

    tr = MultiLearnerTrainer(
        fcnet.loss_fn, opt,
        AlgoConfig(algo=algo, topology=topology, n_learners=n,
                   noise_std=noise_std, **(algo_kwargs or {})),
        alpha_for_diag=lr, engine=engine)

    diags, probes = [], []
    if diag_every:
        tr.add_probe(
            "diag", ProbeSchedule(every=diag_every, start=diag_every),
            lambda st, b: tr.diagnostics(st, b),
            on_result=lambda st, d: (diags.append((int(st.step), d)), st)[1])
    if landscape_every:
        probe_fn = make_trainer_probe(fcnet.loss_fn, alpha=lr,
                                      **(probe_kwargs or {}))

        def on_probe(st, r):
            probes.append((int(st.step), r))
            if controller is not None:
                st = st._replace(opt_state=set_controller_scale(
                    st.opt_state, controller.update(r)))
            return st
        tr.add_probe("landscape", ProbeSchedule(every=landscape_every),
                     probe_fn, on_result=on_probe)

    st = tr.init(key, params)
    supervisor = None
    if fault_plan is not None:
        from repro.core import Membership, Supervisor
        supervisor = Supervisor(tr, Membership(n), fault_plan)
        st = tr.set_membership(st, supervisor.membership)
    losses, stale_max = [], 0.0
    if tr.probes_due(0):   # let a controller engage before the first step
        st, _ = tr.run_probes(st, loader.batch(50_000), step=0)
    # warm-up/compile step excluded from timing
    if supervisor is not None:
        st = supervisor.tick(st, 0)
    st, m = tr.train_step(st, loader.batch(0))
    t0 = time.perf_counter()
    for i in range(1, steps):
        if tr.probes_due(i):
            t_probe = time.perf_counter()
            st, _ = tr.run_probes(st, loader.batch(50_000 + i), step=i)
            t0 += time.perf_counter() - t_probe   # keep step timing clean
        if supervisor is not None:
            st = supervisor.tick(st, i)
        st, m = tr.train_step(st, loader.batch(i))
        losses.append(float(m.loss))
        stale_max = max(stale_max, float(m.staleness_max))
    dt = (time.perf_counter() - t0) / max(steps - 1, 1)
    return {"losses": losses, "diags": diags, "probes": probes,
            "us_per_step": dt * 1e6, "trainer": tr, "state": st,
            "loader": loader, "staleness_max": stale_max,
            "controller": controller, "supervisor": supervisor}


def final_loss(losses, k: int = 10) -> float:
    tail = [x for x in losses[-k:] if math.isfinite(x)]
    return sum(tail) / len(tail) if tail else float("nan")


def fmt(x) -> str:
    if isinstance(x, float):
        return f"{x:.6g}"
    return str(x)
