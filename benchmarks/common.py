"""Shared harness for the paper-table benchmarks (CPU scale).

Every benchmark prints CSV rows `name,us_per_call,derived` (run.py contract)
and writes its full table to results/bench/<name>.csv.
"""
from __future__ import annotations

import csv
import math
import os
import time

import jax

from repro.core import AlgoConfig, MultiLearnerTrainer
from repro.data import ShardedLoader, TemplateImages
from repro.models import fcnet
from repro.optim import sgd

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "bench")


def write_table(name: str, header, rows):
    os.makedirs(RESULTS, exist_ok=True)
    path = os.path.join(RESULTS, f"{name}.csv")
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(header)
        w.writerows(rows)
    return path


def train_fc(algo: str, lr: float, *, n: int = 5, local_batch: int = 400,
             steps: int = 150, seed: int = 0, noise_std: float = 0.01,
             topology: str = "random_pair", diag_every: int = 0,
             dataset=None, optimizer=None, algo_kwargs=None):
    """Returns dict(losses, diags, us_per_step, trainer, state, loader).

    ``algo_kwargs`` are forwarded to AlgoConfig (adpsgd staleness bound /
    straggler injection: max_staleness, slow_learner, slow_factor).
    """
    ds = dataset or TemplateImages()
    loader = ShardedLoader(ds, n_learners=n, local_batch=local_batch,
                           seed=seed)
    key = jax.random.PRNGKey(seed)
    params = fcnet.init_params(key, in_dim=784, hidden=50)
    tr = MultiLearnerTrainer(
        fcnet.loss_fn, optimizer or sgd(lr),
        AlgoConfig(algo=algo, topology=topology, n_learners=n,
                   noise_std=noise_std, **(algo_kwargs or {})),
        alpha_for_diag=lr)
    st = tr.init(key, params)
    losses, diags, stale_max = [], [], 0.0
    # warm-up/compile step excluded from timing
    st, m = tr.train_step(st, loader.batch(0))
    t0 = time.perf_counter()
    for i in range(1, steps):
        st, m = tr.train_step(st, loader.batch(i))
        losses.append(float(m.loss))
        stale_max = max(stale_max, float(m.staleness_max))
        if diag_every and i % diag_every == 0:
            d = tr.diagnostics(st, loader.batch(50_000 + i))
            diags.append((i, d))
    dt = (time.perf_counter() - t0) / max(steps - 1, 1)
    return {"losses": losses, "diags": diags, "us_per_step": dt * 1e6,
            "trainer": tr, "state": st, "loader": loader,
            "staleness_max": stale_max}


def final_loss(losses, k: int = 10) -> float:
    tail = [x for x in losses[-k:] if math.isfinite(x)]
    return sum(tail) / len(tail) if tail else float("nan")


def fmt(x) -> str:
    if isinstance(x, float):
        return f"{x:.6g}"
    return str(x)
