"""Paper Table 4/8 (lr tuning at the largest batch): tuning SSGD's lr down
lets it escape early traps, but DPSGD at full linear-scaled lr still wins."""
from __future__ import annotations

from .common import final_loss, parse_smoke, train_fc, write_table

LRS = (0.0625, 0.125, 0.25, 0.5)


def main(argv=None):
    smoke = parse_smoke(argv)
    steps = 24 if smoke else 120
    lrs = (LRS[1], LRS[3]) if smoke else LRS
    rows = []
    us = 0.0
    for lr in lrs:
        for algo in ("ssgd", "dpsgd"):
            r = train_fc(algo, lr, local_batch=400, steps=steps)
            us = r["us_per_step"]
            rows.append([algo, lr, final_loss(r["losses"])])
    write_table("table4_lr_tuning", ["algo", "lr", "final_loss"], rows)
    best_ssgd = min(r[2] for r in rows if r[0] == "ssgd")
    best_dpsgd = min(r[2] for r in rows if r[0] == "dpsgd")
    derived = (f"best ssgd={best_ssgd:.3f} (needs tuning) best dpsgd="
               f"{best_dpsgd:.3f} (paper T4: DPSGD best across lrs)")
    print(f"table4_lr_tuning,{us:.0f},{derived}")


if __name__ == "__main__":
    main()
