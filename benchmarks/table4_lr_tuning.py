"""Paper Table 4/8 (lr tuning at the largest batch): tuning SSGD's lr down
lets it escape early traps, but DPSGD at full linear-scaled lr still wins."""
from __future__ import annotations

from .common import final_loss, train_fc, write_table

LRS = (0.0625, 0.125, 0.25, 0.5)


def main():
    rows = []
    us = 0.0
    for lr in LRS:
        for algo in ("ssgd", "dpsgd"):
            r = train_fc(algo, lr, local_batch=400, steps=120)
            us = r["us_per_step"]
            rows.append([algo, lr, final_loss(r["losses"])])
    write_table("table4_lr_tuning", ["algo", "lr", "final_loss"], rows)
    best_ssgd = min(r[2] for r in rows if r[0] == "ssgd")
    best_dpsgd = min(r[2] for r in rows if r[0] == "dpsgd")
    derived = (f"best ssgd={best_ssgd:.3f} (needs tuning) best dpsgd="
               f"{best_dpsgd:.3f} (paper T4: DPSGD best across lrs)")
    print(f"table4_lr_tuning,{us:.0f},{derived}")


if __name__ == "__main__":
    main()
