"""Cross-PR benchmark trajectory: align BENCH_PR<N>.json cells, report
speedups/regressions (DESIGN §13).

Loads every ``BENCH_PR*.json`` it can find (the current run's results dir
plus the committed ``benchmarks/history/`` snapshots — a fresh result for
the same PR number shadows the committed one, keeping same-host timings
together), aligns cells across PRs by their stable cell key
(`benchmarks.schema`), and classifies each cell's latest move:

  * ``new``        — cell first appears in the latest PR
  * ``removed``    — cell existed before but the latest PR dropped it
  * ``improved``   — us/step fell below IMPROVED_MARK x previous
  * ``regression`` — us/step rose past the cell's tolerance band
  * ``ok``         — inside the band

The per-cell tolerance band comes from the cell record itself
(``tolerance`` field) or DEFAULT_TOLERANCE — deliberately loose for
wall-clock metrics on shared CI hosts; a real regression (e.g. the ~3x
per-step re-flatten PR 3 removed) blows far past it, load jitter does not.

CLI:
    python -m benchmarks.trajectory [glob ...] [--gate] [--tolerance X]

Without ``--gate`` this is a report (exit 0, writes
``results/bench/trajectory.csv``); with it, any ``regression`` cell exits
non-zero — that is the mode `benchmarks.check_regression` embeds for
``make bench-check``.  Like `benchmarks.schema`, this module must stay
importable without jax.
"""
from __future__ import annotations

import csv
import glob as globlib
import os
import sys

from .schema import HISTORY, SchemaError, load_result, results_dir

DEFAULT_TOLERANCE = 2.0   # per-cell us/step band for cross-run CI noise
IMPROVED_MARK = 0.8       # >=20% faster counts as an improvement
GATE_METRIC = "us_per_step"


def default_globs() -> list[str]:
    return [os.path.join(results_dir(), "BENCH_PR*.json"),
            os.path.join(HISTORY, "BENCH_PR*.json")]


def load_payloads(patterns=None) -> list[dict]:
    """Expand globs/paths -> one validated payload per PR, sorted by PR.

    Earlier patterns win on PR-number collisions (results dir shadows the
    committed history snapshot of the same PR).
    """
    patterns = list(patterns) if patterns else default_globs()
    by_pr: dict[int, dict] = {}
    for pat in patterns:
        paths = sorted(globlib.glob(pat)) if globlib.has_magic(pat) else [pat]
        for path in paths:
            payload = load_result(path)
            by_pr.setdefault(payload["pr"], payload)
    return [by_pr[pr] for pr in sorted(by_pr)]


def build_trajectory(payloads) -> dict[str, list[tuple[int, dict]]]:
    """{cell_key: [(pr, cell), ...]} over PR-ascending payloads."""
    traj: dict[str, list[tuple[int, dict]]] = {}
    for p in sorted(payloads, key=lambda p: p["pr"]):
        for key, cell in p["cells"].items():
            traj.setdefault(key, []).append((p["pr"], cell))
    return traj


def classify(traj: dict, latest_pr: int,
             default_tolerance: float = DEFAULT_TOLERANCE) -> list[dict]:
    """Per-cell trajectory rows, sorted by key.

    ``ratio`` compares the cell's last two appearances on GATE_METRIC
    (latest / previous; < 1 is a speedup).  Cells that never appeared
    twice, or lack the gate metric, carry ratio None.
    """
    rows = []
    for key in sorted(traj):
        series = traj[key]
        prs = [pr for pr, _ in series]
        latest_cell = series[-1][1]
        tol = float(latest_cell.get("tolerance", default_tolerance))
        ratio = None
        if prs[-1] != latest_pr:
            status = "removed"
        elif len(series) == 1:
            status = "new"
        else:
            prev, cur = series[-2][1], series[-1][1]
            a = prev["metrics"].get(GATE_METRIC)
            b = cur["metrics"].get(GATE_METRIC)
            if a and b:
                ratio = b / a
                status = ("regression" if ratio > tol
                          else "improved" if ratio < IMPROVED_MARK else "ok")
            else:
                status = "ok"
        rows.append({"key": key, "status": status, "ratio": ratio,
                     "tolerance": tol, "prs": prs,
                     "metrics": dict(latest_cell["metrics"])})
    return rows


def write_report(rows, path) -> str:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["cell", "status", "us_ratio", "tolerance", "prs",
                    "us_per_step", "tokens_per_s"])
        for r in rows:
            w.writerow([
                r["key"], r["status"],
                f"{r['ratio']:.3f}" if r["ratio"] is not None else "",
                r["tolerance"],
                ";".join(str(p) for p in r["prs"]),
                r["metrics"].get("us_per_step", ""),
                r["metrics"].get("tokens_per_s", ""),
            ])
    return path


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else list(argv)
    gate = "--gate" in argv
    tol = DEFAULT_TOLERANCE
    if "--tolerance" in argv:
        tol = float(argv[argv.index("--tolerance") + 1])
    patterns = [a for i, a in enumerate(argv)
                if not a.startswith("--")
                and (i == 0 or argv[i - 1] != "--tolerance")]
    try:
        payloads = load_payloads(patterns or None)
    except (SchemaError, FileNotFoundError) as e:
        print(f"TRAJECTORY ERROR: {e}", file=sys.stderr)
        return 2
    if len(payloads) < 2:
        prs = [p["pr"] for p in payloads]
        print("trajectory: need >= 2 PRs of BENCH_*.json to align "
              f"(found {prs}); run `python -m benchmarks.matrix --smoke` "
              "and/or `python -m benchmarks.bench_throughput` first",
              file=sys.stderr)
        return 2

    rows = classify(build_trajectory(payloads), payloads[-1]["pr"],
                    default_tolerance=tol)
    prs = [p["pr"] for p in payloads]
    print(f"benchmark trajectory over PRs {prs} "
          f"({len(rows)} cells, tolerance {tol:.2f}x):")
    for r in rows:
        move = (f"{r['ratio']:.2f}x us/step" if r["ratio"] is not None
                else f"PRs {r['prs']}")
        print(f"  [{r['status']:>10}] {r['key']}  {move}")
    counts = {}
    for r in rows:
        counts[r["status"]] = counts.get(r["status"], 0) + 1
    path = write_report(rows, os.path.join(results_dir(), "trajectory.csv"))
    print("trajectory summary: "
          + " ".join(f"{k}={v}" for k, v in sorted(counts.items()))
          + f" -> {os.path.relpath(path)}")

    bad = [r for r in rows if r["status"] == "regression"]
    for r in bad:
        print(f"TRAJECTORY REGRESSION: {r['key']} {r['ratio']:.2f}x "
              f"us/step (band {r['tolerance']:.2f}x, PRs {r['prs']})",
              file=sys.stderr)
    return 1 if (gate and bad) else 0


if __name__ == "__main__":
    sys.exit(main())
