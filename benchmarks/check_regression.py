"""Perf-regression gate over the benchmark matrix (DESIGN §11, §13).

Two layers of gating, one CLI:

1. **Legacy engine-parity contract** (unchanged from PR 3) — for every v1
   ``bench_throughput`` payload (``BENCH_PR3.json``-style, an ``algos``
   table), each algorithm that ships with the flat engine as its default
   (DPSGD/AD-PSGD) must satisfy:

   * flat-engine us/step within the measured CPU parity-noise band of the
     pytree path (TOLERANCE — what "no slower" means on a host where the
     two engines sit at parity and the flat win is HBM traffic on real
     accelerators),
   * the traced flat step's largest concatenate far below the parameter
     count (the per-step re-flatten must not sneak back in),
   * the fused kernel actually dispatched.

   On CPU the engines sit at parity: the fused update and scan driver pay
   back the flat<->tree layout bridge and repeated measurement lands
   within a ±10% noise band around 1.0.  TOLERANCE is that band: a REAL
   regression (the old per-call re-flatten was ~3x on the e2e microbench)
   blows far past it, parity jitter does not flake CI.

2. **Matrix trajectory gate** (PR 6) — when the resolved files span more
   than one PR, every cell shared between consecutive PRs (aligned by the
   stable cell key of `benchmarks.schema`; v1 payloads are adapted) must
   keep its us/step inside the per-cell tolerance band
   (`benchmarks.trajectory`).

Usage:
    python -m benchmarks.check_regression [path-or-glob ...]

With no arguments the single-file PR 3 behavior is preserved:
``results/bench/BENCH_PR3.json`` gets the legacy checks.  ``make
bench-check`` passes ``"results/bench/BENCH_PR*.json"`` so the whole
matrix of the current run is gated.  Exit codes: 0 ok, 1 regression or
contract violation, 2 missing/unreadable input.
"""
from __future__ import annotations

import glob as globlib
import json
import os
import sys

from . import trajectory
from .schema import SchemaError, load_result, results_dir

TOLERANCE = 1.15          # measured CPU parity noise band on the <= gate
CONCAT_FRACTION = 0.25    # step concats must stay << n_elem (RNG-sized)


def check_legacy(payload: dict) -> list[str]:
    """The PR 3 flat-vs-pytree contract on one v1 ``algos`` payload."""
    n_elem = payload["config"]["n_elem"]
    errors = []
    for algo, r in payload["algos"].items():
        ratio = r["flat_over_pytree_ratio"]
        gated = r.get("default_engine_flat", algo in ("dpsgd", "adpsgd"))
        if ratio > TOLERANCE:
            msg = (f"{algo}: flat engine SLOWER than pytree path "
                   f"(paired ratio {ratio:.2f}, "
                   f"{r['flat_us_per_step']:.0f} vs "
                   f"{r['pytree_us_per_step']:.0f} us/step)")
            if gated:
                errors.append(msg)
            else:   # reference measurement: algo ships on the pytree engine
                print(f"note (ungated): {msg}")
        if r["flat_step_max_concat_elems"] >= n_elem * CONCAT_FRACTION:
            errors.append(
                f"{algo}: parameter-sized concatenate back in the traced "
                f"step ({r['flat_step_max_concat_elems']} elems, "
                f"n_elem={n_elem})")
        if gated and not r.get("fused_kernel"):
            errors.append(f"{algo}: flat engine did not take the fused "
                          "kernel path")
        print(f"checked: {algo} flat {r['flat_us_per_step']:.0f} us/step vs "
              f"pytree {r['pytree_us_per_step']:.0f} "
              f"(paired speedup {r['flat_speedup']:.2f}x"
              f"{', gated' if gated else ''}), "
              f"concat {r['flat_step_max_concat_elems']} elems")
    return errors


def main(argv=None) -> int:
    argv = argv if argv is not None else sys.argv[1:]
    patterns = argv or [os.path.join(results_dir(), "BENCH_PR3.json")]

    paths = []
    for pat in patterns:
        matched = sorted(globlib.glob(pat)) if globlib.has_magic(pat) \
            else [pat]
        if not matched:
            print(f"check_regression: no files match {pat!r}",
                  file=sys.stderr)
            return 2
        paths.extend(matched)

    errors = []
    payloads = []
    for path in paths:
        try:
            with open(path) as f:
                raw = json.load(f)
        except FileNotFoundError:
            print(f"check_regression: {path} not found — run "
                  "`python -m benchmarks.bench_throughput` / "
                  "`python -m benchmarks.matrix --smoke` first",
                  file=sys.stderr)
            return 2
        except json.JSONDecodeError as e:
            print(f"check_regression: {path} is not JSON: {e}",
                  file=sys.stderr)
            return 2
        if "schema_version" not in raw and "algos" in raw:
            errors += [f"{os.path.basename(path)}: {e}"
                       for e in check_legacy(raw)]
        try:   # schema contract (v1 files go through the legacy adapter)
            payloads.append(load_result(path))
        except SchemaError as e:
            errors.append(str(e))

    prs = sorted({p["pr"] for p in payloads})
    if len(prs) > 1:
        rows = trajectory.classify(
            trajectory.build_trajectory(payloads), prs[-1])
        shared = [r for r in rows if r["ratio"] is not None]
        print(f"matrix gate: PRs {prs}, {len(rows)} cells "
              f"({len(shared)} aligned across PRs)")
        for r in rows:
            if r["status"] == "regression":
                errors.append(
                    f"cell {r['key']} regressed {r['ratio']:.2f}x us/step "
                    f"(band {r['tolerance']:.2f}x, PRs {r['prs']})")
    elif len(paths) > 1:
        print(f"matrix gate: all files belong to PR {prs} — nothing to "
              "align, skipping the trajectory gate")

    for e in errors:
        print(f"PERF REGRESSION: {e}", file=sys.stderr)
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
