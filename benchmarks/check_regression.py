"""Perf-regression gate for the flat-state engine (DESIGN §11).

Reads the BENCH_PR3.json emitted by benchmarks.bench_throughput and fails
(non-zero exit) unless, for every algorithm that ships with the flat
engine as its default (DPSGD/AD-PSGD):

  * flat-engine us/step stays within the measured CPU parity-noise band of
    the pytree path (TOLERANCE below — what "no slower" means on a host
    where the two engines sit at parity and the flat win is HBM traffic on
    real accelerators), and
  * the traced flat step's largest concatenate stays far below the
    parameter count (the per-step re-flatten must not sneak back in), and
  * the flat path actually dispatched the fused kernel.

Timings come from bench_throughput's chunk-interleaved paired runs.  On
CPU the two engines sit at parity: the flat engine's fused update and scan
driver pay back the flat<->tree layout bridge (unflatten views forward,
cotangent scatter backward, ~0.8 ms/step at smoke scale) and repeated
measurement lands within a ±10% noise band around 1.0 — the decisive flat
win (one HBM pass over {w, remote, g, mu} instead of many) needs actual
memory-bandwidth-bound hardware.  TOLERANCE is set to that measured CPU
noise band: a REAL regression — the old per-call re-flatten was ~3x on the
e2e microbench, a reintroduced per-step flatten costs ~2 extra full passes
— blows far past it, while parity jitter does not flake CI.

Usage:
    python -m benchmarks.check_regression [path/to/BENCH_PR3.json]
"""
from __future__ import annotations

import json
import os
import sys

from .common import RESULTS

TOLERANCE = 1.15          # measured CPU parity noise band on the <= gate
CONCAT_FRACTION = 0.25    # step concats must stay << n_elem (RNG-sized)


def main(argv=None) -> int:
    argv = argv if argv is not None else sys.argv[1:]
    path = argv[0] if argv else os.path.join(RESULTS, "BENCH_PR3.json")
    with open(path) as f:
        payload = json.load(f)

    n_elem = payload["config"]["n_elem"]
    errors = []
    for algo, r in payload["algos"].items():
        ratio = r["flat_over_pytree_ratio"]
        gated = r.get("default_engine_flat", algo in ("dpsgd", "adpsgd"))
        if ratio > TOLERANCE:
            msg = (f"{algo}: flat engine SLOWER than pytree path "
                   f"(paired ratio {ratio:.2f}, "
                   f"{r['flat_us_per_step']:.0f} vs "
                   f"{r['pytree_us_per_step']:.0f} us/step)")
            if gated:
                errors.append(msg)
            else:   # reference measurement: algo ships on the pytree engine
                print(f"note (ungated): {msg}")
        if r["flat_step_max_concat_elems"] >= n_elem * CONCAT_FRACTION:
            errors.append(
                f"{algo}: parameter-sized concatenate back in the traced "
                f"step ({r['flat_step_max_concat_elems']} elems, "
                f"n_elem={n_elem})")
        if gated and not r.get("fused_kernel"):
            errors.append(f"{algo}: flat engine did not take the fused "
                          "kernel path")
        print(f"checked: {algo} flat {r['flat_us_per_step']:.0f} us/step vs "
              f"pytree {r['pytree_us_per_step']:.0f} "
              f"(paired speedup {r['flat_speedup']:.2f}x"
              f"{', gated' if gated else ''}), "
              f"concat {r['flat_step_max_concat_elems']} elems")

    for e in errors:
        print(f"PERF REGRESSION: {e}", file=sys.stderr)
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
