"""Benchmark entrypoint: one function per paper table/figure.
Prints `name,us_per_call,derived` CSV rows; full tables in results/bench/.
``--smoke`` is forwarded to every workload (short-but-complete runs)."""
from __future__ import annotations

import sys
import traceback


def main(argv=None) -> None:
    argv = sys.argv[1:] if argv is None else list(argv)
    from . import (ablation_topology, bench_kernels, bench_throughput,
                   fig2_effective_lr, fig3_straggler, fig4_noise_decomp,
                   matrix, roofline_report, table1_large_batch,
                   table4_lr_tuning, table5_asr_proxy, theorem1_smoothing)
    benches = [
        ("fig2_effective_lr", fig2_effective_lr.main),
        ("fig4_noise_decomp", fig4_noise_decomp.main),
        ("table1_large_batch", table1_large_batch.main),
        ("table4_lr_tuning", table4_lr_tuning.main),
        ("table5_asr_proxy", table5_asr_proxy.main),
        ("theorem1_smoothing", theorem1_smoothing.main),
        ("fig3_straggler", fig3_straggler.main),
        ("ablation_topology", ablation_topology.main),
        ("bench_kernels", bench_kernels.main),
        ("bench_throughput", bench_throughput.main),
        ("bench_matrix", matrix.main),
        ("roofline_report", roofline_report.main),
    ]
    print("name,us_per_call,derived")
    failed = []
    for name, fn in benches:
        try:
            rc = fn(argv)
            # matrix-style mains return an int exit code; figure mains may
            # return their result payload (fig2's losses dict) — not a failure
            if isinstance(rc, int) and rc:
                failed.append(name)
        except Exception:
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
