"""Fault-injection smoke benchmark: the fleet survives crashes mid-run.

Drives the elastic-membership stack (DESIGN §15) end to end on the real
trainer: a seeded :class:`~repro.core.FaultPlan` crashes a learner mid-run,
rejoins it later (consensus-clone ``admit``), and the
:class:`~repro.core.Supervisor` applies every event as a same-shape operand
swap — the compiled step is never invalidated on the randomized-matching
path.  Measured per cell:

  * **us/step** in three windows — healthy fleet, degraded (post-crash),
    and post-rejoin (the "post-resize throughput" of the acceptance gate)
  * **recovery_steps** — how many steps after the crash the training loss
    takes to return to its pre-crash level (the recovery-time measurement)
  * **final loss** and the minimum live-member count seen

``measure_cell`` is the matrix plugin (workload ``elastic`` in
`benchmarks.matrix`); ``main`` is the standalone smoke benchmark wired
into ``make bench-smoke`` (contract row ``bench_faults,us,derived``).
"""
from __future__ import annotations

import math
import time

from .common import final_loss, parse_smoke, write_table

N, LR, LOCAL_BATCH = 5, 0.5, 200
ALGOS = ("dpsgd", "adpsgd")


def _plan(fault: str, steps: int, n: int):
    """The per-cell fault script.  ``crash_rejoin`` is the acceptance
    scenario (die at 1/3, consensus-rejoin at 2/3, straggler throughout);
    ``chaos`` is the seeded random schedule."""
    from repro.core import FaultPlan
    if fault == "crash_rejoin":
        plan = FaultPlan.crash_rejoin(1, steps // 3, 2 * steps // 3)
        return FaultPlan(plan.events + FaultPlan.straggler(0, 2).events)
    if fault == "chaos":
        return FaultPlan.random(0, steps, n, min_active=2)
    raise ValueError(f"unknown fault scenario {fault!r}")


def run_faulted(algo: str, fault: str, *, steps: int, n: int = N,
                engine: str = "flat", seed: int = 0):
    """Train fcnet under a Supervisor + FaultPlan; returns the windowed
    timings, the loss trace, the live-count trace and the fault report."""
    import jax

    from repro.core import (AlgoConfig, Membership, MultiLearnerTrainer,
                            Supervisor)
    from repro.data import ShardedLoader, TemplateImages
    from repro.models import fcnet
    from repro.optim import sgd

    loader = ShardedLoader(TemplateImages(), n_learners=n,
                           local_batch=LOCAL_BATCH, seed=seed)
    key = jax.random.PRNGKey(seed)
    params = fcnet.init_params(key, in_dim=784, hidden=50)
    kw = {"max_staleness": 4} if algo == "adpsgd" else {}
    tr = MultiLearnerTrainer(
        fcnet.loss_fn, sgd(LR),
        AlgoConfig(algo=algo, topology="random_pair", n_learners=n,
                   noise_std=0.01, **kw),
        engine=engine)
    st = tr.init(key, params)
    sup = Supervisor(tr, Membership(n), _plan(fault, steps, n))
    st = tr.set_membership(st, sup.membership)

    st = sup.tick(st, 0)
    st, m = tr.train_step(st, loader.batch(0))   # warm-up/compile
    jax.block_until_ready(m.loss)
    losses, times, n_act = [], [], []
    for i in range(1, steps):
        st = sup.tick(st, i)
        t0 = time.perf_counter()
        st, m = tr.train_step(st, loader.batch(i))
        loss = float(m.loss)                     # blocks on the step
        times.append(time.perf_counter() - t0)
        losses.append(loss)
        n_act.append(int(m.n_active))
    return {"losses": losses, "times": times, "n_active": n_act,
            "report": sup.report, "state": st, "trainer": tr}


def _window_us(times, lo, hi):
    w = times[lo:hi]
    return 1e6 * sum(w) / len(w) if w else float("nan")


def recovery_steps(losses, crash_step: int) -> int:
    """Steps after the crash until the loss trace returns to its pre-crash
    level (min over the healthy window); -1 if it never does."""
    pre = [x for x in losses[:crash_step] if math.isfinite(x)]
    if not pre:
        return -1
    floor = min(pre)
    for j in range(crash_step, len(losses)):
        if math.isfinite(losses[j]) and losses[j] <= floor:
            return j - crash_step
    return -1


def measure_cell(algo: str, fault: str, *, engine: str = "flat",
                 smoke: bool = False):
    """Matrix plugin for the ``elastic`` workload: metrics + extra."""
    steps = 36 if smoke else 150
    r = run_faulted(algo, fault, steps=steps, engine=engine)
    rep = r["report"]
    crash = rep.crashes[0][0] if rep.crashes else steps // 3
    rejoin = rep.rejoins[-1][0] if rep.rejoins else crash
    # loss/time indices are step-1 (step 0 is the excluded warm-up)
    metrics = {
        "us_per_step": _window_us(r["times"], 0, None),
        "us_per_step_resized": _window_us(r["times"], rejoin, None),
        "recovery_steps": float(recovery_steps(r["losses"],
                                               max(crash - 1, 0))),
        "final_loss": final_loss(r["losses"]),
        "n_active_min": float(min(r["n_active"])),
    }
    extra = {"fault": fault, "steps": steps,
             "crashes": len(rep.crashes), "rejoins": len(rep.rejoins),
             "evictions": len(rep.evictions), "retries": len(rep.retries),
             "dropped_rounds": rep.dropped_rounds,
             "interventions": rep.interventions}
    return metrics, extra


def main(argv=None):
    smoke = parse_smoke(argv)
    t0 = time.perf_counter()
    rows, derived_bits = [], {}
    for algo in ALGOS:
        m, x = measure_cell(algo, "crash_rejoin", smoke=smoke)
        rows.append([algo, "crash_rejoin", m["us_per_step"],
                     m["us_per_step_resized"], m["recovery_steps"],
                     m["final_loss"], m["n_active_min"],
                     x["interventions"]])
        derived_bits[algo] = m
    write_table("bench_faults",
                ["algo", "fault", "us_per_step", "us_per_step_resized",
                 "recovery_steps", "final_loss", "n_active_min",
                 "interventions"], rows)
    us = (time.perf_counter() - t0) * 1e6
    d, a = derived_bits["dpsgd"], derived_bits["adpsgd"]
    derived = (f"crash+rejoin survived: dpsgd loss={d['final_loss']:.3f} "
               f"recovery={d['recovery_steps']:.0f} steps; adpsgd "
               f"loss={a['final_loss']:.3f} recovery={a['recovery_steps']:.0f}"
               f" steps (fleet floor n={d['n_active_min']:.0f})")
    print(f"bench_faults,{us:.0f},{derived}")


if __name__ == "__main__":
    main()
