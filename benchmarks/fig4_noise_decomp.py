"""Paper Fig. 4: decomposition of the DPSGD noise into the minibatch part
Delta_S and the landscape-dependent part Delta2; Delta2 >> Delta_S early and
decays as training smooths the landscape."""
from __future__ import annotations

from .common import parse_smoke, train_fc, write_table


def main(argv=None):
    steps = 30 if parse_smoke(argv) else 120
    r = train_fc("dpsgd", 0.5, steps=steps, diag_every=10)
    rows = [[step, float(d.delta_s), float(d.delta_2),
             float(d.sigma_w_sq), float(d.alpha_e)]
            for step, d in r["diags"]]
    write_table("fig4_noise_decomp",
                ["step", "delta_s", "delta_2", "sigma_w_sq", "alpha_e"], rows)
    early = rows[0]
    late = rows[-1]
    ratio_early = early[2] / max(early[1], 1e-20)
    derived = (f"delta2/deltaS early={ratio_early:.1f} "
               f"delta2 early={early[2]:.2e} late={late[2]:.2e} "
               "(paper: Delta2>>DeltaS early, decays)")
    print(f"fig4_noise_decomp,{r['us_per_step']:.0f},{derived}")


if __name__ == "__main__":
    main()
