"""Beyond-paper ablation (App. F territory): gossip topology sweep at the
critical lr — full avg (=SSGD weight dynamics), ring, random-pair (paper's
recipe), hierarchical-equivalent torus, and solo (no mixing).  Shows the
spectral-gap / noise trade-off: solo never consensus-averages (loss stays
high across learners), full averaging kills the landscape-dependent noise
(back to SSGD behaviour), ring/random-pair hit the sweet spot."""
from __future__ import annotations

from repro.core import topology as topo

from .common import final_loss, train_fc, write_table

LR = 0.5


def main():
    rows = []
    us = 0.0
    for name in ("full", "ring", "torus", "random_pair", "solo"):
        r = train_fc("dpsgd", LR, steps=130, topology=name)
        us = r["us_per_step"]
        m = topo.make_mixing_fn(name, 5)(__import__("jax").random.PRNGKey(0))
        rows.append([name, float(topo.spectral_gap(m)),
                     final_loss(r["losses"])])
    write_table("ablation_topology", ["topology", "spectral_gap",
                                      "final_loss"], rows)
    d = {r[0]: r[2] for r in rows}
    derived = (f"full={d['full']:.3f} ring={d['ring']:.3f} "
               f"pair={d['random_pair']:.3f} solo={d['solo']:.3f} "
               f"(partial averaging beats full & none)")
    print(f"ablation_topology,{us:.0f},{derived}")


if __name__ == "__main__":
    main()
