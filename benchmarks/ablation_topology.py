"""Beyond-paper ablation (App. F territory): the full GossipSchedule sweep
at the critical lr — every compiled topology (static: full/ring/torus/
hierarchical/exp; time-varying: one-peer exponential, random matchings with
multi-round mixing) plus solo, each dispatching the fused flat engine
(DESIGN §12).

Two stories in one table:

  * the paper's noise trade-off: solo never consensus-averages, full
    averaging kills the landscape-dependent noise (back to SSGD behaviour),
    the sparse schedules hit the sweet spot;
  * the schedule analyzer: per-schedule measured consensus contraction vs
    the product-of-(1-λ₂) bound (`measured_gap >= gap_bound`; time-varying
    schedules beat their per-step bound by a wide margin — that headroom is
    why one-peer exponential is usable at one collective per step).

CSV columns (benchmarks/README.md contract):
  topology, K, period, rounds_per_step, fused, gap_bound, measured_gap,
  final_loss, consensus_dist
Smoke mode (``--smoke``, used by `make bench-check`) shortens training but
keeps every schedule and every column.
"""
from __future__ import annotations

import sys

import numpy as np

from repro.core import learner_var
from repro.core.schedule import make_schedule, spectral_gap_profile

from .common import final_loss, train_fc, write_table

LR = 0.5
TOPOLOGIES = ("full", "ring", "torus", "random_pair", "solo",
              "hierarchical", "exp", "one_peer_exp", "random_matching")
N = 8


def run_topology(name: str, *, steps: int = 130, n: int = N) -> dict:
    """One GossipSchedule cell: train dpsgd on ``name`` + profile the
    schedule.  Shared by this script's sweep and benchmarks.matrix's
    ``topology`` workload plugin."""
    kw = {"gossip_rounds": 2} if name == "random_matching" else {}
    r = train_fc("dpsgd", LR, n=n, steps=steps, topology=name,
                 algo_kwargs=kw)
    tr = r["trainer"]
    sched = make_schedule(name, n, rounds=kw.get("gossip_rounds", 1))
    prof = spectral_gap_profile(sched, window=16)
    consensus = float(np.sqrt(float(
        learner_var(tr.params_tree(r["state"])))))
    return {
        "topology": name,
        "K": sched.K if sched else 0,
        "period": sched.period if sched else 0,
        "rounds_per_step": sched.rounds_per_step if sched else 0,
        "fused": int(tr._fused is not None),
        "gap_bound": round(prof["gap_bound"], 6),
        "measured_gap": round(prof["measured_gap"], 6),
        "final_loss": final_loss(r["losses"]),
        "consensus_dist": consensus,
        "us_per_step": r["us_per_step"],
    }


COLUMNS = ("topology", "K", "period", "rounds_per_step", "fused",
           "gap_bound", "measured_gap", "final_loss", "consensus_dist")


def main(argv=None):
    argv = sys.argv[1:] if argv is None else argv
    smoke = "--smoke" in argv
    steps = 40 if smoke else 130
    rows = []
    us = 0.0
    for name in TOPOLOGIES:
        r = run_topology(name, steps=steps)
        us += r["us_per_step"]
        rows.append([r[c] for c in COLUMNS])
    write_table("ablation_topology", list(COLUMNS), rows)
    d = {r[0]: r for r in rows}
    # every scheduled topology must have run the fused kernel; the analyzer
    # must never report contraction faster than measured
    assert all(r[4] == 1 for r in rows if r[0] != "solo"), rows
    assert all(r[6] >= r[5] - 1e-9 for r in rows), rows
    derived = (f"full={d['full'][7]:.3f} ring={d['ring'][7]:.3f} "
               f"pair={d['random_pair'][7]:.3f} solo={d['solo'][7]:.3f} "
               "(partial averaging beats full & none); one_peer_exp "
               f"measured_gap={d['one_peer_exp'][6]:.2f} vs per-step bound "
               f"{d['one_peer_exp'][5]:.2f} at 1 collective/step; all "
               "schedules fused")
    print(f"ablation_topology,{us / max(len(rows), 1):.0f},{derived}")


if __name__ == "__main__":
    main()
