"""Declarative benchmark matrix: one sweep spec, one BENCH_PR<N>.json
(DESIGN §13, ROADMAP item 5).

The spec declares the sweep over (model x algo x topology x n x precision
x engine) per workload; ``expand`` turns it into runnable cells (cartesian
product minus excludes, deterministic order), and a per-workload plugin
registry maps each cell onto one of the existing runners:

  * ``throughput``  -> `bench_throughput.measure_cell` (per-engine us/step
    and tokens/s — the same drivers and cell axes as the legacy
    BENCH_PR3.json, so the trajectory aligns across the schema break)
  * ``topology``    -> `ablation_topology.run_topology` (GossipSchedule
    sweep: contraction bound + loss per schedule)
  * ``large_batch`` -> `table1_large_batch.run_cell` (AdaScale-style
    batch/LR scaling axis — the paper's Table 1 regime)
  * ``elastic``     -> `faults.measure_cell` (crash / consensus-rejoin /
    seeded chaos under the membership Supervisor: recovery time and
    post-resize throughput, ISSUE 8)
  * ``serving``     -> `serving.measure_cell` (continuous vs static
    batching under open-loop Poisson arrivals: tokens/s + p50/p99 latency
    on the paged decode path, ISSUE 7)

Each PR's run emits ``results/bench/BENCH_PR<N>.json`` in the
schema-versioned format of `benchmarks.schema`; `benchmarks.trajectory`
aligns those across PRs and `benchmarks.check_regression` gates them.

CLI (wired into ``make bench-smoke`` / ``bench-check``):
    python -m benchmarks.matrix [--smoke] [--pr N]

``--smoke`` trims the axis lists (SPEC.smoke below) and shortens training;
cell KEYS are unchanged, so smoke and full runs align on their shared
cells.  Spec expansion and the registry are importable without jax (the
runners import the training stack lazily) so tests can exercise them
standalone.
"""
from __future__ import annotations

import dataclasses
import itertools
import sys
import time

from . import schema

CURRENT_PR = 8   # bump per PR: the emitted artifact is BENCH_PR<N>.json


@dataclasses.dataclass(frozen=True)
class MatrixSpec:
    """Axes are {axis: (values...)}; per-workload axes override ``base``.

    ``exclude`` entries are partial axis dicts — a cell is dropped when
    every listed key matches.  ``smoke`` holds per-workload axis overrides
    for the trimmed CI run (never new axis NAMES: smoke subsets values).
    """
    base: dict
    workloads: dict
    exclude: tuple = ()
    smoke: dict = dataclasses.field(default_factory=dict)


SPEC = MatrixSpec(
    base={"model": ("fcnet",), "precision": ("f32",), "n": (5,)},
    workloads={
        "throughput": {
            "algo": ("ssgd", "dpsgd", "adpsgd", "ssgd_star"),
            "engine": ("flat", "pytree"),
            "topology": ("random_pair",),
        },
        "topology": {
            "algo": ("dpsgd",),
            "engine": ("flat",),
            "n": (8,),
            "topology": ("full", "ring", "torus", "random_pair", "solo",
                         "hierarchical", "exp", "one_peer_exp",
                         "random_matching"),
        },
        "large_batch": {
            "algo": ("ssgd", "dpsgd", "ssgd_autolr"),
            "engine": ("auto",),
            "topology": ("random_pair",),
            "batch_scale": (1, 2, 4),
        },
        # elastic sweeps the fault scenario under the membership harness:
        # crash+consensus-rejoin and the seeded chaos schedule (DESIGN §15)
        "elastic": {
            "algo": ("dpsgd", "adpsgd"),
            "engine": ("flat",),
            "topology": ("random_pair",),
            "fault": ("crash_rejoin", "chaos"),
        },
        # serving sweeps the ADMISSION engine, not the trainer engine; the
        # greedy/solo axes are degenerate but keep the cell key canonical
        "serving": {
            "model": ("tiny-lm",),
            "algo": ("greedy",),
            "topology": ("solo",),
            "n": (4,),                      # decode slots
            "engine": ("continuous", "static"),
            "rate": (0.25, 1.0),            # requests per engine step
        },
    },
    # ssgd_star draws per-leaf weight noise — the flat engine refuses it
    # (trainer raises); it is measured on the pytree reference only.
    exclude=({"algo": "ssgd_star", "engine": "flat"},),
    smoke={
        "throughput": {"algo": ("ssgd", "dpsgd", "adpsgd")},
        "topology": {"topology": ("full", "ring", "random_pair", "solo")},
        # ssgd_autolr's probe compile dominates smoke wall-clock: full only
        "large_batch": {"algo": ("ssgd", "dpsgd"), "batch_scale": (1, 4)},
        # one scripted scenario per algo keeps smoke wall-clock bounded;
        # the chaos schedule runs in the full sweep
        "elastic": {"fault": ("crash_rejoin",)},
    },
)


def expand(spec: MatrixSpec, smoke: bool = False) -> list[dict]:
    """Spec -> ordered list of cell axes dicts (workload key included)."""
    cells = []
    for wl, wl_axes in spec.workloads.items():
        axes_def = {**spec.base, **wl_axes}
        if smoke:
            for k, vals in spec.smoke.get(wl, {}).items():
                assert k in axes_def, (wl, k)
                axes_def[k] = vals
        names = list(axes_def)
        for combo in itertools.product(*(axes_def[k] for k in names)):
            axes = {"workload": wl, **dict(zip(names, combo))}
            if any(all(axes.get(k) == v for k, v in ex.items())
                   for ex in spec.exclude):
                continue
            cells.append(axes)
    return cells


# -- per-workload plugin registry --------------------------------------------

REGISTRY: dict = {}


def workload(name: str):
    def deco(fn):
        REGISTRY[name] = fn
        return fn
    return deco


@workload("throughput")
def _run_throughput(axes: dict, smoke: bool):
    # keep chunk == bench_throughput.CHUNK: the flat engine's run_steps
    # scan amortizes a fixed per-call cost over the chunk, so a smaller
    # smoke chunk would skew flat cells vs the legacy BENCH_PR3 history
    from .bench_throughput import measure_cell
    return measure_cell(axes["algo"], axes["engine"],
                        chunks=2 if smoke else 8)


@workload("topology")
def _run_topology(axes: dict, smoke: bool):
    from .ablation_topology import run_topology
    r = run_topology(axes["topology"], steps=20 if smoke else 130)
    metrics = {k: float(r[k]) for k in
               ("us_per_step", "final_loss", "consensus_dist",
                "gap_bound", "measured_gap")}
    extra = {k: r[k] for k in ("K", "period", "rounds_per_step", "fused")}
    return metrics, extra


@workload("large_batch")
def _run_large_batch(axes: dict, smoke: bool):
    from .table1_large_batch import run_cell
    r = run_cell(axes["algo"], axes["batch_scale"],
                 steps=12 if smoke else 120)
    metrics = {k: float(r[k]) for k in
               ("us_per_step", "final_loss", "autolr_scale")}
    return metrics, {"nB": r["nB"], "lr": r["lr"]}


@workload("elastic")
def _run_elastic(axes: dict, smoke: bool):
    # recovery-time + post-resize throughput under the seeded fault
    # harness (DESIGN §15): the acceptance metrics for the elastic fleet
    from .faults import measure_cell
    return measure_cell(axes["algo"], axes["fault"],
                        engine=axes["engine"], smoke=smoke)


@workload("serving")
def _run_serving(axes: dict, smoke: bool):
    from .serving import MAX_LEN, PAGE_SIZE, measure_cell
    m = measure_cell(axes["engine"], axes["rate"], smoke=smoke)
    extra = {"page_size": PAGE_SIZE, "max_len": MAX_LEN,
             "n_requests": int(m.pop("n_requests"))}
    return m, extra


# -- execution ----------------------------------------------------------------

def run_matrix(spec: MatrixSpec = SPEC, *, smoke: bool = False,
               pr: int = CURRENT_PR):
    """Run every cell; returns (payload, errors).  Failed cells are
    reported and dropped from the payload rather than killing the run."""
    import jax
    payload = schema.new_payload(pr, {
        "smoke": smoke, "backend": jax.default_backend(),
        "device_count": jax.device_count()})
    errors = []
    cells = expand(spec, smoke=smoke)
    for i, axes in enumerate(cells):
        label = schema.cell_key(axes)
        t0 = time.perf_counter()
        try:
            metrics, extra = REGISTRY[axes["workload"]](axes, smoke)
        except Exception as e:  # noqa: BLE001 — cell isolation is the point
            errors.append(f"{label}: {type(e).__name__}: {e}")
            print(f"  cell {i + 1}/{len(cells)} FAILED {label}: {e}",
                  file=sys.stderr)
            continue
        key, cell = schema.make_cell(axes, metrics, extra=extra)
        payload["cells"][key] = cell
        print(f"  cell {i + 1}/{len(cells)} {label} "
              f"us/step={metrics['us_per_step']:.0f} "
              f"({time.perf_counter() - t0:.1f}s)")
    return payload, errors


def main(argv=None) -> int:
    import json
    import os

    from .common import parse_smoke, write_table

    argv = sys.argv[1:] if argv is None else list(argv)
    smoke = parse_smoke(argv)
    pr = int(argv[argv.index("--pr") + 1]) if "--pr" in argv else CURRENT_PR

    t0 = time.perf_counter()
    payload, errors = run_matrix(SPEC, smoke=smoke, pr=pr)
    bad = schema.validate(payload)
    assert not bad, bad   # the emitter must honor its own schema

    out_dir = schema.results_dir()
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"BENCH_PR{pr}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)

    rows = [[key, c["axes"]["workload"], c["axes"]["algo"],
             c["axes"]["topology"], c["axes"]["n"], c["axes"]["engine"],
             c["metrics"]["us_per_step"],
             c["metrics"].get("tokens_per_s", ""),
             c["metrics"].get("final_loss", "")]
            for key, c in payload["cells"].items()]
    write_table("bench_matrix",
                ["cell", "workload", "algo", "topology", "n", "engine",
                 "us_per_step", "tokens_per_s", "final_loss"], rows)

    n = len(payload["cells"])
    us = (time.perf_counter() - t0) * 1e6 / max(n, 1)
    by_wl = {}
    for c in payload["cells"].values():
        by_wl[c["axes"]["workload"]] = by_wl.get(c["axes"]["workload"], 0) + 1
    derived = (f"{n} cells ({'smoke' if smoke else 'full'}: "
               + " ".join(f"{k}={v}" for k, v in sorted(by_wl.items()))
               + f") -> {os.path.basename(path)} schema v"
               f"{schema.SCHEMA_VERSION}"
               + (f"; {len(errors)} FAILED" if errors else ""))
    print(f"bench_matrix,{us:.0f},{derived}")
    for e in errors:
        print(f"MATRIX CELL FAILED: {e}", file=sys.stderr)
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
