"""Paper Fig. 3 + App. F: straggler immunity / runtime model.

TPU SPMD is bulk-synchronous, so the paper's *asynchrony* benefit does not
transfer (DESIGN.md §2); what remains is the communication-volume benefit.
This benchmark computes per-step wall-clock from the roofline comm model for
SSGD (all-reduce of grads) vs DPSGD-einsum vs DPSGD-ppermute under a k-times
straggling link, for the paper's SWB-300-like 165 MB model and for yi-34b."""
from __future__ import annotations

import time

from repro.configs import get_config
from repro.launch.roofline import ICI_BW

from .common import write_table

STRAGGLE = (1.0, 2.0, 5.0)


def step_time(p_bytes: float, n_learners: int, algo: str, slow: float):
    if algo == "ssgd":            # ring all-reduce: 2P(n-1)/n, sync on all
        vol = 2 * p_bytes * (n_learners - 1) / n_learners
        return vol / (ICI_BW / slow)
    if algo == "dpsgd_einsum":    # all-gather every replica
        vol = n_learners * p_bytes
        return vol / (ICI_BW / slow)
    # ppermute ring: exchange with 2 neighbors only; a slow link delays only
    # its pair, amortized 1/n of steps at full slowdown
    vol = 2 * p_bytes
    eff = 1.0 + (slow - 1.0) / n_learners
    return vol / ICI_BW * eff


def main():
    t0 = time.perf_counter()
    rows = []
    models = {"swb300_lstm_165MB": 165e6,
              "yi-34b": get_config("yi-34b").n_params() * 2 / 16}  # per shard
    for name, p in models.items():
        for slow in STRAGGLE:
            for algo in ("ssgd", "dpsgd_einsum", "dpsgd_ppermute"):
                rows.append([name, slow, algo,
                             step_time(p, 16, algo, slow) * 1e3])
    write_table("fig3_straggler", ["model", "straggle_x", "algo",
                                   "comm_ms_per_step"], rows)
    us = (time.perf_counter() - t0) * 1e6
    s5 = {r[2]: r[3] for r in rows if r[0] == "swb300_lstm_165MB"
          and r[1] == 5.0}
    derived = (f"5x-straggler comm ms: ssgd={s5['ssgd']:.1f} "
               f"dpsgd_ppermute={s5['dpsgd_ppermute']:.1f} "
               f"(paper Fig3: DPSGD immune)")
    print(f"fig3_straggler,{us:.0f},{derived}")


if __name__ == "__main__":
    main()
