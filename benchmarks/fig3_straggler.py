"""Paper Fig. 3 + App. F: straggler immunity, measured on the real code path.

Trains sync pairwise DPSGD vs async AD-PSGD with an injected straggler
(learner 0 takes ``slow_factor`` ticks per local step, injected through
``FaultPlan.straggler`` — the same seeded fault path the elastic-membership
harness replays, DESIGN §15) through the actual MultiLearnerTrainer and
reports, per algorithm:

  * measured us/step of the jitted train step (the real compute cost)
  * effective wall-clock per tick under the straggler: synchronous gossip
    barriers on the slowest learner every tick (x slow_factor), AD-PSGD
    proceeds against the straggler's stale published buffer (x 1)
  * final training loss and the max buffer staleness actually observed —
    the convergence price of asynchrony (bounded by max_staleness)

The barrier inflation is the one modeled quantity: SPMD hardware is
bulk-synchronous, so true overlap cannot be timed in-process (DESIGN.md §2);
everything else — the training dynamics, the staleness, the losses, the
step cost — is measured, not simulated.  App. F's roofline communication
model lives on in benchmarks/roofline_report.py.
"""
from __future__ import annotations

import time

from repro.core import FaultPlan

from .common import final_loss, parse_smoke, train_fc, write_table

SLOW_FACTORS = (1, 2, 5)
N, LR, STEPS, TAU = 8, 0.5, 120, 4


def main(argv=None):
    smoke = parse_smoke(argv)
    steps = 24 if smoke else STEPS
    slow_factors = SLOW_FACTORS[-1:] if smoke else SLOW_FACTORS
    t0 = time.perf_counter()
    rows = []
    derived_bits = {}
    # the sync run does not depend on the straggle factor (only its barrier
    # inflation does) — train it once, reuse across the sweep
    sync = train_fc("dpsgd", LR, n=N, steps=steps)
    for slow in slow_factors:
        adp = train_fc("adpsgd", LR, n=N, steps=steps,
                       algo_kwargs=dict(max_staleness=TAU),
                       fault_plan=FaultPlan.straggler(0, slow))
        for name, run, tick_scale in (("dpsgd_sync", sync, slow),
                                      ("adpsgd", adp, 1)):
            us = run["us_per_step"]
            rows.append([name, slow, us, us * tick_scale,
                         final_loss(run["losses"]), run["staleness_max"]])
        if slow == slow_factors[-1]:
            derived_bits = {
                "sync_ms": sync["us_per_step"] * slow / 1e3,
                "async_ms": adp["us_per_step"] / 1e3,
                "async_loss": final_loss(adp["losses"]),
                "sync_loss": final_loss(sync["losses"]),
            }
    write_table("fig3_straggler",
                ["algo", "straggle_x", "us_per_step_measured",
                 "us_per_tick_with_straggler", "final_loss",
                 "staleness_max_seen"], rows)
    us = (time.perf_counter() - t0) * 1e6
    derived = (f"5x-straggler tick ms: sync={derived_bits['sync_ms']:.1f} "
               f"async={derived_bits['async_ms']:.1f}; final loss "
               f"sync={derived_bits['sync_loss']:.3f} "
               f"async={derived_bits['async_loss']:.3f} "
               "(paper Fig3: DPSGD immune)")
    print(f"fig3_straggler,{us:.0f},{derived}")


if __name__ == "__main__":
    main()
