"""Paper Fig. 2(a)+(b): DPSGD vs SSGD vs SSGD* at a large learning rate in
the large-batch setting, with the self-adjusting effective learning rate
alpha_e(t) and weight variance sigma_w^2(t) trajectories — and, new, the
landscape probe's Eq. 4 *prediction* alpha_e ~= alpha(1 - (alpha/2)
Tr(HC)/sigma_w^2) overlaid against the measured alpha_e (DESIGN §10)."""
from __future__ import annotations

from .common import final_loss, parse_smoke, train_fc, write_table

LR = 0.5
STEPS = 140


def main(argv=None):
    smoke = parse_smoke(argv)
    steps, every = (40, 10) if smoke else (STEPS, 20)
    rows = []
    runs = {}
    for algo in ("ssgd", "dpsgd", "ssgd_star"):
        r = train_fc(algo, LR, steps=steps, diag_every=every,
                     landscape_every=every)
        runs[algo] = r
        pred = {step: p for step, p in r["probes"]}
        for step, d in r["diags"]:
            p = pred.get(step)
            rows.append([algo, step, r["losses"][step - 1],
                         float(d.alpha_e), float(d.sigma_w_sq),
                         float(d.delta_s), float(d.delta_2),
                         float(p.alpha_e_pred) if p else float("nan"),
                         float(p.sharpness) if p else float("nan"),
                         float(p.trace_hc) if p else float("nan")])
    # SSGD* noise sensitivity.  Paper: only a finely tuned sigma0 converges;
    # at this 42k-param scale ALL sigmas converge (isotropic escape is
    # dimension-dependent) — honest negative, see EXPERIMENTS.md.
    star = {}
    for std in (0.1,) if smoke else (0.1, 0.01, 0.001):
        rs = train_fc("ssgd_star", LR, steps=steps, noise_std=std)
        star[std] = final_loss(rs["losses"])
        rows.append([f"ssgd_star(std={std})", steps, star[std],
                     float("nan"), float("nan"), float("nan"), float("nan"),
                     float("nan"), float("nan"), float("nan")])
    write_table("fig2_effective_lr",
                ["algo", "step", "loss", "alpha_e", "sigma_w_sq",
                 "delta_s", "delta_2", "alpha_e_pred", "sharpness",
                 "trace_hc"], rows)
    res = {a: final_loss(r["losses"]) for a, r in runs.items()}
    us = sum(r["us_per_step"] for r in runs.values()) / 3
    # Eq.4 fidelity: mean |pred - measured| / alpha over the DPSGD probes
    dp = runs["dpsgd"]
    pred = {s: p for s, p in dp["probes"]}
    errs = [abs(float(pred[s].alpha_e_pred) - float(d.alpha_e)) / LR
            for s, d in dp["diags"] if s in pred]
    eq4 = sum(errs) / len(errs) if errs else float("nan")
    derived = (f"final_loss ssgd={res['ssgd']:.3f} dpsgd={res['dpsgd']:.3f} "
               f"ssgd*={res['ssgd_star']:.3f}; eq4 |pred-meas|/alpha="
               f"{eq4:.3f}; ssgd* sweep "
               + " ".join(f"s{k}={v:.2f}" for k, v in star.items())
               + " (paper: DPSGD converges, SSGD fails; SSGD*-inferiority "
               "does not reproduce at 42k params — honest negative)")
    print(f"fig2_effective_lr,{us:.0f},{derived}")
    return res


if __name__ == "__main__":
    main()
