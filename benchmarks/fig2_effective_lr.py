"""Paper Fig. 2(a)+(b): DPSGD vs SSGD vs SSGD* at a large learning rate in
the large-batch setting, with the self-adjusting effective learning rate
alpha_e(t) and weight variance sigma_w^2(t) trajectories."""
from __future__ import annotations

from .common import final_loss, train_fc, write_table

LR = 0.5
STEPS = 140


def main():
    rows = []
    runs = {}
    for algo in ("ssgd", "dpsgd", "ssgd_star"):
        r = train_fc(algo, LR, steps=STEPS, diag_every=20)
        runs[algo] = r
        for step, d in r["diags"]:
            rows.append([algo, step, r["losses"][step - 1],
                         float(d.alpha_e), float(d.sigma_w_sq),
                         float(d.delta_s), float(d.delta_2)])
    # SSGD* noise sensitivity.  Paper: only a finely tuned sigma0 converges;
    # at this 42k-param scale ALL sigmas converge (isotropic escape is
    # dimension-dependent) — honest negative, see EXPERIMENTS.md.
    star = {}
    for std in (0.1, 0.01, 0.001):
        rs = train_fc("ssgd_star", LR, steps=STEPS, noise_std=std)
        star[std] = final_loss(rs["losses"])
        rows.append([f"ssgd_star(std={std})", STEPS, star[std],
                     float("nan"), float("nan"), float("nan"), float("nan")])
    write_table("fig2_effective_lr",
                ["algo", "step", "loss", "alpha_e", "sigma_w_sq",
                 "delta_s", "delta_2"], rows)
    res = {a: final_loss(r["losses"]) for a, r in runs.items()}
    us = sum(r["us_per_step"] for r in runs.values()) / 3
    derived = (f"final_loss ssgd={res['ssgd']:.3f} dpsgd={res['dpsgd']:.3f} "
               f"ssgd*={res['ssgd_star']:.3f}; ssgd* sweep "
               + " ".join(f"s{k}={v:.2f}" for k, v in star.items())
               + " (paper: DPSGD converges, SSGD fails; SSGD*-inferiority "
               "does not reproduce at 42k params — honest negative)")
    print(f"fig2_effective_lr,{us:.0f},{derived}")
    return res


if __name__ == "__main__":
    main()
