"""Paper Table 1 (CIFAR-10 batch-size scaling, proxied at CPU scale):
linear-scaling-rule lr for increasing total batch; SSGD vs DPSGD final loss,
plus the new closed-loop ``ssgd_autolr`` column (DESIGN §10): plain SSGD
whose LR multiplier is clamped online from probed sharpness — the explicit
version of DPSGD's implicit self-adjustment.  The scenario: SSGD+AutoLR
survives the large-batch LRs where SSGD diverges."""
from __future__ import annotations

from .common import final_loss, train_fc, write_table

BASE_LOCAL, BASE_LR = 100, 0.125   # nB=500 baseline
SCALES = (1, 2, 4)                  # nB = 500, 1000, 2000


def main():
    rows = []
    us = 0.0
    for s in SCALES:
        for algo in ("ssgd", "dpsgd", "ssgd_autolr"):
            r = train_fc(algo, BASE_LR * s, local_batch=BASE_LOCAL * s,
                         steps=120)
            us = r["us_per_step"]
            ctl = r["controller"]
            rows.append([algo, 5 * BASE_LOCAL * s, BASE_LR * s,
                         final_loss(r["losses"]),
                         ctl.scale if ctl is not None else 1.0])
    write_table("table1_large_batch",
                ["algo", "nB", "lr", "final_loss", "autolr_scale"], rows)
    big = {r[0]: r[3] for r in rows if r[1] == 5 * BASE_LOCAL * SCALES[-1]}
    derived = (f"largest-batch loss ssgd={big['ssgd']:.3f} "
               f"dpsgd={big['dpsgd']:.3f} ssgd_autolr={big['ssgd_autolr']:.3f}"
               " (paper T1: DPSGD wins at bs=8192; AutoLR keeps SSGD alive)")
    print(f"table1_large_batch,{us:.0f},{derived}")


if __name__ == "__main__":
    main()
