"""Paper Table 1 (CIFAR-10 batch-size scaling, proxied at CPU scale):
linear-scaling-rule lr for increasing total batch; SSGD vs DPSGD final loss,
plus the new closed-loop ``ssgd_autolr`` column (DESIGN §10): plain SSGD
whose LR multiplier is clamped online from probed sharpness — the explicit
version of DPSGD's implicit self-adjustment.  The scenario: SSGD+AutoLR
survives the large-batch LRs where SSGD diverges.

``run_cell`` is the per-(algo, batch_scale) unit benchmarks.matrix reuses
as its ``large_batch`` workload plugin — the AdaScale-style batch/LR
scaling axis of the sweep spec."""
from __future__ import annotations

from .common import final_loss, parse_smoke, train_fc, write_table

BASE_LOCAL, BASE_LR = 100, 0.125   # nB=500 baseline
SCALES = (1, 2, 4)                  # nB = 500, 1000, 2000
N = 5


def run_cell(algo: str, scale: int, *, steps: int = 120) -> dict:
    """One (algo, batch-scale) cell under the linear LR scaling rule."""
    r = train_fc(algo, BASE_LR * scale, local_batch=BASE_LOCAL * scale,
                 steps=steps)
    ctl = r["controller"]
    return {"algo": algo, "nB": N * BASE_LOCAL * scale,
            "lr": BASE_LR * scale, "final_loss": final_loss(r["losses"]),
            "autolr_scale": float(ctl.scale) if ctl is not None else 1.0,
            "us_per_step": r["us_per_step"]}


def main(argv=None):
    smoke = parse_smoke(argv)
    steps = 24 if smoke else 120
    scales = SCALES[::2] if smoke else SCALES   # keep baseline + largest
    rows = []
    us = 0.0
    for s in scales:
        for algo in ("ssgd", "dpsgd", "ssgd_autolr"):
            r = run_cell(algo, s, steps=steps)
            us = r["us_per_step"]
            rows.append([algo, r["nB"], r["lr"], r["final_loss"],
                         r["autolr_scale"]])
    write_table("table1_large_batch",
                ["algo", "nB", "lr", "final_loss", "autolr_scale"], rows)
    big = {r[0]: r[3] for r in rows if r[1] == N * BASE_LOCAL * scales[-1]}
    derived = (f"largest-batch loss ssgd={big['ssgd']:.3f} "
               f"dpsgd={big['dpsgd']:.3f} ssgd_autolr={big['ssgd_autolr']:.3f}"
               " (paper T1: DPSGD wins at bs=8192; AutoLR keeps SSGD alive)")
    print(f"table1_large_batch,{us:.0f},{derived}")


if __name__ == "__main__":
    main()
