"""Paper Table 3/5 (ASR heldout loss, proxied at CPU scale).

The SWB tasks' defining stress (paper footnote 3) is the highly uneven
class distribution (32k zipfian classes).  Proxy: framewise classification
with 100 zipf(1.2)-distributed template classes, large batch (nB=2000),
lr scan.  Expected pattern (paper Table 5): parity at safe lr; at the
critical lr SSGD fails while DPSGD converges; at extreme lr both fail."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .common import final_loss, write_table


@dataclasses.dataclass(frozen=True)
class ZipfTemplates:
    n_classes: int = 100
    alpha: float = 1.2
    seed: int = 5

    def _templates(self):
        key = jax.random.PRNGKey(self.seed)
        return (jax.random.uniform(key, (self.n_classes, 784))
                > 0.8).astype(jnp.float32)

    def sample(self, key, b):
        k1, k2 = jax.random.split(key)
        ranks = jnp.arange(1, self.n_classes + 1, dtype=jnp.float32)
        lab = jax.random.categorical(
            k1, jnp.broadcast_to(-self.alpha * jnp.log(ranks),
                                 (b, self.n_classes)))
        x = jnp.clip(0.2 + 0.2 * jax.random.normal(k2, (b, 784))
                     + 0.8 * self._templates()[lab], 0, 1)
        return {"image": x, "label": lab.astype(jnp.int32)}


def main(argv=None):
    from repro.models import fcnet

    from .common import parse_smoke
    smoke = parse_smoke(argv)
    steps = 24 if smoke else 120
    ds = ZipfTemplates()
    rows = []
    us = 0.0
    for lr in (0.5,) if smoke else (0.25, 0.5, 1.0):
        for algo in ("ssgd", "dpsgd"):
            # 100-class head needs its own init: patch via custom optimizer? no:
            # train_fc uses fcnet.init_params(n_classes=10); do it inline here
            import jax as _jax
            from repro.core import AlgoConfig, MultiLearnerTrainer
            from repro.data import ShardedLoader
            from repro.optim import sgd
            loader = ShardedLoader(ds, n_learners=5, local_batch=400)
            key = _jax.random.PRNGKey(0)
            params = fcnet.init_params(key, in_dim=784, hidden=50,
                                       n_classes=100)
            tr = MultiLearnerTrainer(
                fcnet.loss_fn, sgd(lr),
                AlgoConfig(algo=algo, topology="random_pair", n_learners=5))
            st = tr.init(key, params)
            import time
            st, m = tr.train_step(st, loader.batch(0))
            t0 = time.perf_counter()
            losses = []
            for i in range(1, steps):
                st, m = tr.train_step(st, loader.batch(i))
                losses.append(float(m.loss))
            us = (time.perf_counter() - t0) / (steps - 1) * 1e6
            heldout = float(tr.eval_loss(st, loader.eval_batch(512)))
            rows.append([algo, lr, final_loss(losses), heldout])
    write_table("table5_asr_proxy", ["algo", "lr", "train_loss", "heldout"],
                rows)
    crit = {r[0]: r[3] for r in rows if r[1] == 0.5}
    derived = (f"critical-lr heldout ssgd={crit['ssgd']:.3f} "
               f"dpsgd={crit['dpsgd']:.3f} (paper T5: SSGD fails, DPSGD ok)")
    print(f"table5_asr_proxy,{us:.0f},{derived}")


if __name__ == "__main__":
    main()
