"""Aggregates results/dryrun/*.json into the EXPERIMENTS.md roofline table."""
from __future__ import annotations

import glob
import json
import os

from .common import write_table

DRYRUN = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def load(mesh="pod_16x16", algo="dpsgd", backend="einsum", tag=None):
    out = []
    for f in sorted(glob.glob(os.path.join(DRYRUN, "*.json"))):
        r = json.load(open(f))
        if r.get("mesh") != mesh or r.get("status") != "ok":
            continue
        if r.get("algo") != algo or r.get("backend") != backend:
            continue
        parts = os.path.basename(f)[:-5].split("__")
        has_tag = len(parts) > 5
        if (tag is None) == has_tag or (tag and tag not in parts):
            continue
        out.append(r)
    return out


def main(argv=None):
    # --smoke accepted for workload-CLI uniformity: aggregation is already
    # cheap (no training), so smoke == full here
    recs = load()
    rows = []
    for r in recs:
        rl = r["roofline"]
        rows.append([
            r["arch"], r["shape"], rl["bottleneck"],
            f"{rl['t_compute_s']:.4g}", f"{rl['t_memory_s']:.4g}",
            f"{rl['t_collective_s']:.4g}",
            f"{r['useful_flops_ratio']:.3f}",
            f"{r['memory']['total_hbm_bytes'] / 1e9:.1f}",
        ])
    write_table("roofline_single_pod",
                ["arch", "shape", "bottleneck", "t_compute_s", "t_memory_s",
                 "t_collective_s", "useful_flops_ratio", "hbm_GB_per_chip"],
                rows)
    n_coll = sum(1 for r in rows if r[2] == "collective")
    print(f"roofline_report,0,{len(rows)} baselines aggregated; "
          f"{n_coll} collective-bound")


if __name__ == "__main__":
    main()
