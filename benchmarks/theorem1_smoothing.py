"""Theorem 1 verification.

(a) Analytic non-smooth case: L(w) = G*||w||_1 is G-Lipschitz with unbounded
    gradient-Lipschitz constant at the kinks.  Nesterov-Spokoiny Lemma 2
    (used by the paper) bounds the smoothed landscape at 2G/sigma — we
    measure the empirical l_s of L~ for a sweep of sigma and check the
    ~1/sigma decay.  This is the regime the theorem addresses (the paper
    invokes it for ReLU nets whose raw l_s can be "close to +inf").
(b) FC-net data point: the same probe on the paper's MNIST net at init
    (reported, not asserted: at generic points the raw landscape is locally
    smooth and the MC estimator variance dominates — an honest limitation
    of sampling-based smoothness probes, noted in EXPERIMENTS.md).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.smoothing import estimate_smoothness
from repro.data import TemplateImages
from repro.models import fcnet

from .common import parse_smoke, write_table

G = 1.0


def rough_loss(params, batch):
    return G * jnp.sum(jnp.abs(params["w"])) + 0.0 * jnp.sum(batch["x"])


def main(argv=None):
    smoke = parse_smoke(argv)
    t0 = time.perf_counter()
    params = {"w": jnp.full((64,), 0.01)}
    batch = {"x": jnp.zeros((1,))}
    key = jax.random.PRNGKey(0)
    rows = []
    ls_raw = float(estimate_smoothness(rough_loss, params, batch, key,
                                       sigma=0.0, n_pairs=6,
                                       probe_radius=0.02))
    rows.append(["l1_analytic", 0.0, ls_raw, float("nan")])
    for sigma in (0.1, 0.8) if smoke else (0.1, 0.2, 0.4, 0.8):
        ls = float(estimate_smoothness(rough_loss, params, batch, key,
                                       sigma=sigma, n_pairs=6, n_mc=64,
                                       probe_radius=0.02))
        rows.append(["l1_analytic", sigma, ls, 2 * G / sigma])

    # FC-net data point (reported, not asserted)
    ds = TemplateImages()
    fb = ds.sample(jax.random.PRNGKey(1), 256)
    fp = fcnet.init_params(jax.random.PRNGKey(2), in_dim=784, hidden=50)
    for sigma in (0.2,) if smoke else (0.0, 0.2):
        ls = float(estimate_smoothness(fcnet.loss_fn, fp, fb,
                                       jax.random.PRNGKey(3), sigma=sigma,
                                       n_pairs=4, n_mc=32,
                                       probe_radius=0.02))
        rows.append(["fcnet_init", sigma, ls, float("nan")])

    us = (time.perf_counter() - t0) * 1e6 / len(rows)
    write_table("theorem1_smoothing",
                ["landscape", "sigma_w", "empirical_l_s", "bound_2G_over_s"],
                rows)
    sm = [r for r in rows if r[0] == "l1_analytic" and r[1] > 0]
    decays = all(sm[i][2] > sm[i + 1][2] for i in range(len(sm) - 1))
    within = all(r[2] <= r[3] * 1.5 for r in sm)
    derived = (f"raw l_s={ls_raw:.1f}; smoothed l_s "
               f"{sm[0][2]:.2f}@s=0.1 -> {sm[-1][2]:.2f}@s=0.8 "
               f"monotone={decays} within 1.5x of 2G/sigma={within}")
    print(f"theorem1_smoothing,{us:.0f},{derived}")
    assert decays, sm


if __name__ == "__main__":
    main()
