import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.launch.sharding import leaf_spec


class _K:
    def __init__(self, k):
        self.key = k


def _spec(path_names, shape, model=16, learner=None):
    path = tuple(_K(n) for n in path_names)
    leaf = jax.ShapeDtypeStruct(tuple(shape), jnp.float32)
    return leaf_spec(path, leaf, model, learner_axes=learner)


def test_megatron_pairs():
    # column-parallel in, row-parallel out: only ONE all-reduce per block
    assert _spec(("mlp", "w1"), (1024, 4096)) == P(None, "model")
    assert _spec(("mlp", "w2"), (4096, 1024)) == P("model", None)
    assert _spec(("mixer", "wq"), (1024, 2048)) == P(None, "model")
    assert _spec(("mixer", "wo"), (2048, 1024)) == P("model", None)


def test_norms_replicated():
    assert _spec(("norm1",), (1024,)) == P(None)


def test_expert_parallel_when_divisible():
    assert _spec(("mlp", "w1"), (128, 4096, 1536)) == P("model", None, None)
    # 40 experts % 16 != 0 -> shard the ff dim instead
    assert _spec(("mlp", "w1"), (40, 1536, 512)) == P(None, None, "model")
    assert _spec(("mlp", "w2"), (40, 512, 1536)) == P(None, "model", None)


def test_learner_axis_prepended():
    s = _spec(("mlp", "w1"), (16, 1024, 4096), learner=("pod", "data"))
    assert s == P(("pod", "data"), None, "model")


def test_indivisible_replicates():
    assert _spec(("mixer", "wk"), (100, 6), model=16) == P(None, None)


def test_vocab_sharding():
    assert _spec(("embed",), (256256, 4096)) == P("model", None)
