"""Per-kernel shape/dtype sweeps against the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.gossip_mix import gossip_mix_update, flatten_for_kernel
from repro.kernels.ops import dpsgd_fused_update, flash_attention


@pytest.mark.parametrize("T,K", [(256, 1), (512, 2), (1024, 3)])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_gossip_kernel_sweep(T, K, dtype):
    key = jax.random.PRNGKey(T + K)
    ks = jax.random.split(key, 4)
    w = jax.random.normal(ks[0], (T, 128), dtype)
    nb = jax.random.normal(ks[1], (K, T, 128), dtype)
    g = jax.random.normal(ks[2], (T, 128), dtype)
    mu = jax.random.normal(ks[3], (T, 128), dtype)
    coefs = jnp.concatenate([jnp.array([0.5]),
                             jnp.full((K,), 0.5 / K)]).astype(jnp.float32)
    w1, m1 = gossip_mix_update(w, nb, g, mu, coefs, lr=0.1, beta=0.9,
                               interpret=True)
    w2, m2 = ref.gossip_mix_update_ref(w, nb, g, mu, coefs, lr=0.1, beta=0.9)
    np.testing.assert_allclose(np.asarray(w1), np.asarray(w2), atol=1e-5)
    np.testing.assert_allclose(np.asarray(m1), np.asarray(m2), atol=1e-5)


@pytest.mark.parametrize("S,hd,H,KV", [(128, 64, 4, 4), (256, 64, 4, 2),
                                       (256, 128, 2, 1)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("kw", [dict(causal=True),
                                dict(causal=True, window=64),
                                dict(causal=False),
                                dict(causal=True, attn_softcap=50.0)])
def test_flash_attention_sweep(S, hd, H, KV, dtype, kw):
    key = jax.random.PRNGKey(S + hd)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (1, H, S, hd)).astype(dtype)
    k = jax.random.normal(ks[1], (1, KV, S, hd)).astype(dtype)
    v = jax.random.normal(ks[2], (1, KV, S, hd)).astype(dtype)
    o1 = flash_attention_fwd(q, k, v, block_q=64, block_k=64, interpret=True,
                             **kw)
    o2 = ref.flash_attention_ref(q, k, v, **kw)
    atol = 2e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32), atol=atol)


def test_flash_attention_model_layout_and_grad():
    key = jax.random.PRNGKey(9)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (2, 64, 4, 32))
    k = jax.random.normal(ks[1], (2, 64, 2, 32))
    v = jax.random.normal(ks[2], (2, 64, 2, 32))

    def f(q_, k_, v_):
        return jnp.sum(flash_attention(q_, k_, v_, causal=True) ** 2)
    g = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    for gi in g:
        assert bool(jnp.isfinite(gi).all())


def test_flatten_roundtrip():
    tree = {"a": jnp.arange(10.0), "b": {"c": jnp.ones((3, 5))}}
    view, unflatten = flatten_for_kernel(tree)
    assert view.shape[1] == 128
    back = unflatten(view)
    np.testing.assert_allclose(np.asarray(back["a"]), np.asarray(tree["a"]))
    np.testing.assert_allclose(np.asarray(back["b"]["c"]),
                               np.asarray(tree["b"]["c"]))


def test_dpsgd_fused_update_tree():
    key = jax.random.PRNGKey(10)
    tree = {"w": jax.random.normal(key, (33, 7)), "b": jnp.ones((5,))}
    nbr = jax.tree_util.tree_map(lambda x: x + 1.0, tree)
    g = jax.tree_util.tree_map(jnp.ones_like, tree)
    mu = jax.tree_util.tree_map(jnp.zeros_like, tree)
    new_w, new_mu = dpsgd_fused_update(tree, [nbr], g, mu, [0.5, 0.5],
                                       lr=0.1, beta=0.9)
    # mixed = (w + (w+1))/2 = w + 0.5 ; mu = g = 1 ; new = mixed - 0.1
    np.testing.assert_allclose(np.asarray(new_w["w"]),
                               np.asarray(tree["w"] + 0.5 - 0.1), atol=1e-5)
    np.testing.assert_allclose(np.asarray(new_mu["b"]), 1.0, atol=1e-6)
