"""Per-kernel shape/dtype sweeps against the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import paged_decode_attention_fwd
from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.gossip_mix import flatten_for_kernel, gossip_mix_update
from repro.kernels.ops import (dpsgd_fused_update, flash_attention,
                               flat_gossip_update, paged_decode_attention)


@pytest.mark.parametrize("T,K", [(256, 1), (512, 2), (1024, 3)])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_gossip_kernel_sweep(T, K, dtype):
    key = jax.random.PRNGKey(T + K)
    ks = jax.random.split(key, 4)
    w = jax.random.normal(ks[0], (T, 128), dtype)
    nb = jax.random.normal(ks[1], (K, T, 128), dtype)
    g = jax.random.normal(ks[2], (T, 128), dtype)
    mu = jax.random.normal(ks[3], (T, 128), dtype)
    coefs = jnp.concatenate([jnp.array([0.5]),
                             jnp.full((K,), 0.5 / K)]).astype(jnp.float32)
    w1, m1 = gossip_mix_update(w, nb, g, mu, coefs, lr=0.1, beta=0.9,
                               interpret=True)
    w2, m2 = ref.gossip_mix_update_ref(w, nb, g, mu, coefs, lr=0.1, beta=0.9)
    np.testing.assert_allclose(np.asarray(w1), np.asarray(w2), atol=1e-5)
    np.testing.assert_allclose(np.asarray(m1), np.asarray(m2), atol=1e-5)


@pytest.mark.parametrize("S,hd,H,KV", [(128, 64, 4, 4), (256, 64, 4, 2),
                                       (256, 128, 2, 1)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("kw", [dict(causal=True),
                                dict(causal=True, window=64),
                                dict(causal=False),
                                dict(causal=True, attn_softcap=50.0)])
def test_flash_attention_sweep(S, hd, H, KV, dtype, kw):
    key = jax.random.PRNGKey(S + hd)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (1, H, S, hd)).astype(dtype)
    k = jax.random.normal(ks[1], (1, KV, S, hd)).astype(dtype)
    v = jax.random.normal(ks[2], (1, KV, S, hd)).astype(dtype)
    o1 = flash_attention_fwd(q, k, v, block_q=64, block_k=64, interpret=True,
                             **kw)
    o2 = ref.flash_attention_ref(q, k, v, **kw)
    atol = 2e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32), atol=atol)


def _paged_operands(S, hd, H, KV, page, max_pages, lengths, seed=0):
    """Random paged K/V pool + a shuffled (non-identity) page table."""
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 3)
    n_pages = 1 + S * max_pages            # page 0 = scratch, never mapped
    q = jax.random.normal(ks[0], (S, H, hd))
    kp = jax.random.normal(ks[1], (n_pages, page, KV, hd))
    vp = jax.random.normal(ks[2], (n_pages, page, KV, hd))
    rng = np.random.default_rng(seed)
    table = jnp.asarray(rng.permutation(np.arange(1, n_pages))
                        .reshape(S, max_pages), jnp.int32)
    return q, kp, vp, table, jnp.asarray(lengths, jnp.int32)


@pytest.mark.parametrize("H,KV", [(4, 4), (4, 2), (4, 1)])
@pytest.mark.parametrize("kw", [dict(), dict(attn_softcap=30.0),
                                dict(window=6)])
def test_paged_decode_attention_kernel_vs_oracle(H, KV, kw):
    """Serving decode grid: ragged per-slot lengths (including an empty
    slot), page-table indirection, GQA/MQA grouping, softcap and sliding
    window — Pallas (interpret) against the jnp oracle."""
    page, max_pages, hd = 4, 4, 16
    lengths = [1, 5, 12, 0]                 # ragged; slot 3 is length-0
    q, kp, vp, table, ln = _paged_operands(len(lengths), hd, H, KV,
                                           page, max_pages, lengths)
    o1 = paged_decode_attention_fwd(q, kp, vp, table, ln, interpret=True,
                                    **kw)
    o2 = ref.paged_decode_attention_ref(q, kp, vp, table, ln, **kw)
    live = np.array(lengths) > 0
    np.testing.assert_allclose(np.asarray(o1)[live], np.asarray(o2)[live],
                               atol=1e-5)
    # length-0 slots: both sides produce the same finite filler (a uniform
    # average of the scratch page) that the scheduler never reads
    assert np.isfinite(np.asarray(o1)).all()


def test_paged_decode_attention_dispatcher_backends():
    """ops.paged_decode_attention: auto resolves to the oracle on CPU and
    the forced-pallas path (interpret) agrees with it."""
    page, max_pages, hd, H, KV = 4, 2, 8, 4, 2
    q, kp, vp, table, ln = _paged_operands(2, hd, H, KV, page, max_pages,
                                           [3, 7], seed=4)
    auto = paged_decode_attention(q, kp, vp, table, ln, backend="auto")
    oracle = paged_decode_attention(q, kp, vp, table, ln, backend="ref")
    np.testing.assert_array_equal(np.asarray(auto), np.asarray(oracle))
    kernel = paged_decode_attention(q, kp, vp, table, ln, backend="pallas")
    np.testing.assert_allclose(np.asarray(kernel), np.asarray(oracle),
                               atol=1e-5)


def test_flash_attention_model_layout_and_grad():
    key = jax.random.PRNGKey(9)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (2, 64, 4, 32))
    k = jax.random.normal(ks[1], (2, 64, 2, 32))
    v = jax.random.normal(ks[2], (2, 64, 2, 32))

    def f(q_, k_, v_):
        return jnp.sum(flash_attention(q_, k_, v_, causal=True) ** 2)
    g = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    for gi in g:
        assert bool(jnp.isfinite(gi).all())


@pytest.mark.parametrize("n,T,K", [(4, 256, 1), (5, 336, 1), (8, 512, 2),
                                   (6, 336, 4), (8, 512, 4)])
@pytest.mark.parametrize("has_mu,wd", [(True, 0.0), (False, 0.0),
                                       (True, 0.01)])
def test_batched_gossip_kernel_sweep(n, T, K, has_mu, wd):
    """Learner-major batched kernel (scalar-prefetch neighbor gather) vs the
    jnp oracle at arbitrary static K (pairwise, ring, torus-like K=4):
    momentum on/off, weight decay, per-learner lr scale, a solo learner and
    an inactive (straggler) learner, non-multiple-of-block T."""
    key = jax.random.PRNGKey(n * T + K)
    ks = jax.random.split(key, 5)
    w = jax.random.normal(ks[0], (n, T, 128))
    remote = jax.random.normal(ks[1], (n, T, 128))
    g = jax.random.normal(ks[2], (n, T, 128))
    mu = jax.random.normal(ks[3], (n, T, 128)) if has_mu else None
    if K == 1:
        partner = jnp.roll(jnp.arange(n), 1).at[0].set(0)   # learner 0 solo
        partners = partner[None].astype(jnp.int32)
        self_c = jnp.where(partner == jnp.arange(n), 1.0, 0.5)
        mix = jnp.stack([self_c, 1.0 - self_c], axis=1)
    else:
        idx = jnp.arange(n)
        partners = jnp.stack([(idx + s) % n
                              for s in range(1, K + 1)]).astype(jnp.int32)
        mix = jnp.full((n, K + 1), 1.0 / (K + 1))
    scale = jnp.linspace(0.5, 1.5, n)[:, None]              # per-learner lr
    active = jnp.ones((n,)).at[n - 1].set(0.0)[:, None]     # straggler
    coefs = jnp.concatenate([mix, scale, active], axis=1).astype(jnp.float32)

    w1, m1 = flat_gossip_update(w, remote, g, mu, partners, coefs,
                                lr=0.1, beta=0.9, weight_decay=wd,
                                backend="pallas")
    w2, m2 = flat_gossip_update(w, remote, g, mu, partners, coefs,
                                lr=0.1, beta=0.9, weight_decay=wd,
                                backend="ref")
    np.testing.assert_allclose(np.asarray(w1), np.asarray(w2), atol=1e-5)
    if has_mu:
        np.testing.assert_allclose(np.asarray(m1), np.asarray(m2), atol=1e-5)
        # the inactive learner's momentum streams through untouched
        np.testing.assert_array_equal(np.asarray(m1[n - 1]),
                                      np.asarray(mu[n - 1]))
    # inactive learner's weights unchanged; solo learner mixes with itself
    np.testing.assert_array_equal(np.asarray(w1[n - 1]), np.asarray(w[n - 1]))


@pytest.mark.parametrize("has_mu", [True, False])
def test_batched_kernel_publish_mode(has_mu):
    """AD-PSGD publish mode: stale-remote select + published-buffer rewrite
    in the same pass, kernel vs oracle, and against the unfused reference
    composition (where -> plain kernel -> where)."""
    n, T = 6, 256
    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    w = jax.random.normal(ks[0], (n, T, 128))
    buf = jax.random.normal(ks[1], (n, T, 128))
    g = jax.random.normal(ks[2], (n, T, 128))
    mu = jax.random.normal(ks[3], (n, T, 128)) if has_mu else None
    partner = jnp.array([1, 0, 3, 2, 5, 4])
    partners = partner[None].astype(jnp.int32)
    mix = jnp.tile(jnp.array([0.5, 0.5]), (n, 1))
    scale = jnp.ones((n, 1))
    active = jnp.ones((n,)).at[0].set(0.0)
    fresh = jnp.zeros((n,)).at[2].set(1.0).at[3].set(1.0)
    coefs = jnp.concatenate(
        [mix, scale, active[:, None], fresh[partner][:, None],
         jnp.maximum(active, fresh)[:, None]], axis=1).astype(jnp.float32)

    outs = {}
    for backend in ("pallas", "ref"):
        outs[backend] = flat_gossip_update(
            w, w, g, mu, partners, coefs, lr=0.1, beta=0.9, buffer=buf,
            backend=backend)
    for a, b in zip(outs["pallas"], outs["ref"]):
        if a is not None:
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-5)
    # unfused reference composition
    remote = jnp.where(fresh[:, None, None] > 0.5, w, buf)
    mixed = 0.5 * w + 0.5 * remote[partner]
    mu_new = (0.9 * mu + g) if has_mu else g
    stepped = mixed - 0.1 * mu_new
    w_exp = jnp.where(active[:, None, None] > 0.5, stepped, w)
    buf_exp = jnp.where(jnp.maximum(active, fresh)[:, None, None] > 0.5,
                        w_exp, buf)
    np.testing.assert_allclose(np.asarray(outs["ref"][0]), np.asarray(w_exp),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(outs["ref"][2]),
                               np.asarray(buf_exp), atol=1e-5)
    # inactive learner 0: weights and momentum untouched, nothing published
    np.testing.assert_array_equal(np.asarray(outs["pallas"][0][0]),
                                  np.asarray(w[0]))
    np.testing.assert_array_equal(np.asarray(outs["pallas"][2][0]),
                                  np.asarray(buf[0]))


@pytest.mark.parametrize("name", ["ring", "torus", "full", "hierarchical",
                                  "exp", "one_peer_exp", "random_pair",
                                  "random_matching"])
def test_schedule_tables_drive_kernel_parity(name):
    """Fused-vs-oracle parity on the EXACT tables every compiled schedule
    emits (K=1..5 across the set, multi-round cycles, padded self-loop
    slots), with the straggler mask and a per-learner lr scale folded in —
    the operands the flat engine really dispatches (DESIGN §12)."""
    from repro.core.schedule import make_schedule
    n, T = 8, 336                                     # non-multiple-of-256 T
    s = make_schedule(name, n, rounds=2)
    ks = jax.random.split(jax.random.PRNGKey(11), 4)
    w = jax.random.normal(ks[0], (n, T, 128))
    g = jax.random.normal(ks[1], (n, T, 128))
    mu = jax.random.normal(ks[2], (n, T, 128))
    scale = jnp.linspace(0.5, 1.5, n)[:, None]
    active = jnp.ones((n,)).at[n - 1].set(0.0)[:, None]     # straggler
    for step in range(max(2, s.period)):
        for partners, coefs in s.step_rounds(jax.random.fold_in(ks[3], step),
                                             step):
            full = jnp.concatenate(
                [coefs, scale, active], axis=1).astype(jnp.float32)
            outs = [flat_gossip_update(w, w, g, mu, partners, full,
                                       lr=0.1, beta=0.9, backend=b)
                    for b in ("pallas", "ref")]
            for a, b in zip(*outs):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           atol=1e-5, err_msg=name)
            # straggler streams through untouched under every schedule
            np.testing.assert_array_equal(np.asarray(outs[0][0][n - 1]),
                                          np.asarray(w[n - 1]))
            w, mu = outs[0][0], outs[0][1]


def test_batched_kernel_solo_learner_keeps_self_mix():
    """coefs [1, 0]: the solo learner's 'mix' is exactly its own weights
    (the update still applies) — mirrors mix_pair_gather semantics."""
    n, T = 4, 256
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    w = jax.random.normal(ks[0], (n, T, 128))
    g = jax.random.normal(ks[1], (n, T, 128))
    partners = jnp.array([[1, 0, 3, 2]], jnp.int32)
    coefs = jnp.tile(jnp.array([1.0, 0.0, 1.0, 1.0], jnp.float32), (n, 1))
    w1, _ = flat_gossip_update(w, w, g, None, partners, coefs, lr=0.1,
                               backend="pallas")
    np.testing.assert_allclose(np.asarray(w1), np.asarray(w - 0.1 * g),
                               atol=1e-6)


def test_flatten_roundtrip():
    tree = {"a": jnp.arange(10.0), "b": {"c": jnp.ones((3, 5))}}
    view, unflatten = flatten_for_kernel(tree)
    assert view.shape[1] == 128
    back = unflatten(view)
    np.testing.assert_allclose(np.asarray(back["a"]), np.asarray(tree["a"]))
    np.testing.assert_allclose(np.asarray(back["b"]["c"]),
                               np.asarray(tree["b"]["c"]))


def test_dpsgd_fused_update_tree():
    key = jax.random.PRNGKey(10)
    tree = {"w": jax.random.normal(key, (33, 7)), "b": jnp.ones((5,))}
    nbr = jax.tree_util.tree_map(lambda x: x + 1.0, tree)
    g = jax.tree_util.tree_map(jnp.ones_like, tree)
    mu = jax.tree_util.tree_map(jnp.zeros_like, tree)
    new_w, new_mu = dpsgd_fused_update(tree, [nbr], g, mu, [0.5, 0.5],
                                       lr=0.1, beta=0.9)
    # mixed = (w + (w+1))/2 = w + 0.5 ; mu = g = 1 ; new = mixed - 0.1
    np.testing.assert_allclose(np.asarray(new_w["w"]),
                               np.asarray(tree["w"] + 0.5 - 0.1), atol=1e-5)
    np.testing.assert_allclose(np.asarray(new_mu["b"]), 1.0, atol=1e-6)
