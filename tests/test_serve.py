"""Serving subsystem tests (ISSUE 7): paged-decode parity against the
rotating-buffer path, the continuous-batching engine's scheduling
invariants, and the consensus-view bridge.

Parity strategy (DESIGN §14): when every slot shares the same position and
the paged cache's logical capacity (max_pages * page_size) equals the
rotating buffer length, `paged_decode_step` must be BITWISE equal to
`decode_step` — the paged oracle gathers the logical K/V buffer through the
page table and then runs the exact einsum/softmax chain of the rotating
path, so any drift means a real indexing bug, not float noise.  The
engine-level tests then cover what the rotating path cannot do at all:
ragged per-slot positions, mid-flight joins, slot recycling, and
page-pool exhaustion.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.model import build_model
from repro.serve import (ConsensusBridge, OutOfPages, PageAllocator,
                         ServeEngine, served_divergence)

PAGE, MAX_PAGES = 4, 4
BUF = PAGE * MAX_PAGES          # rotating buf == paged logical capacity


def _model(arch):
    cfg = get_config(arch).smoke_config()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    return api, params


@pytest.fixture(scope="module")
def dense():
    return _model("transformer-100m")


@pytest.fixture(scope="module")
def ssm():
    return _model("xlstm-350m")


def _shuffled_table(n_slots, seed=0):
    """Non-identity page table: distinct physical pages (never page 0) in
    shuffled order, so parity also proves the gather really indirects."""
    rng = np.random.default_rng(seed)
    pages = rng.permutation(np.arange(1, 1 + n_slots * MAX_PAGES))
    return jnp.asarray(pages.reshape(n_slots, MAX_PAGES), jnp.int32)


# -- paged vs rotating decode: bitwise ---------------------------------------

@pytest.mark.parametrize("arch", ["transformer-100m",        # dense
                                  "granite-moe-3b-a800m",    # moe
                                  "xlstm-350m"])             # ssm
def test_paged_decode_bitwise_matches_rotating(arch):
    """Six shared-position steps crossing a page boundary (page_size=4),
    through a shuffled page table, across the architecture families."""
    api, params = _model(arch)
    B = 3
    cache_r = api.init_cache(params, B, BUF)
    cache_p = api.init_paged_cache(params, B, 1 + B * MAX_PAGES, PAGE)
    table = _shuffled_table(B)
    key = jax.random.PRNGKey(1)
    for pos in range(6):
        toks = jax.random.randint(jax.random.fold_in(key, pos), (B, 1), 0,
                                  api.cfg.vocab, jnp.int32)
        lr_, cache_r = api.decode_step(params, cache_r, toks, pos)
        lp_, cache_p = api.paged_decode_step(
            params, cache_p, toks, jnp.full((B,), pos, jnp.int32), table)
        np.testing.assert_array_equal(
            np.asarray(lr_[..., :api.cfg.vocab]),
            np.asarray(lp_[..., :api.cfg.vocab]),
            err_msg=f"{arch} pos={pos}")


def test_paged_decode_matches_prefill_logits(dense):
    """Token-at-a-time paged decode reproduces the prefill (apply) logits
    to float tolerance — the engine's prefill-rides-decode contract."""
    api, params = dense
    S = 7
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, S), 0,
                              api.cfg.vocab, jnp.int32)
    full = np.asarray(api.apply(params, {"tokens": toks})[0, :, :api.cfg.vocab])
    cache = api.init_paged_cache(params, 1, 1 + MAX_PAGES, PAGE)
    table = jnp.arange(1, 1 + MAX_PAGES, dtype=jnp.int32)[None]
    got = []
    for pos in range(S):
        lg, cache = api.paged_decode_step(
            params, cache, toks[:, pos:pos + 1],
            jnp.full((1,), pos, jnp.int32), table)
        got.append(np.asarray(lg[0, 0, :api.cfg.vocab]))
    np.testing.assert_allclose(np.stack(got), full, atol=1e-4, rtol=1e-4)


def test_paged_decode_ragged_positions_match_solo_runs(dense):
    """Slots at DIFFERENT positions in one fused step (impossible on the
    rotating path) must each match a solo run of the same stream."""
    api, params = dense
    key = jax.random.PRNGKey(3)
    streams = [jax.random.randint(jax.random.fold_in(key, i), (n,), 0,
                                  api.cfg.vocab, jnp.int32)
               for i, n in enumerate((6, 3, 1))]
    solo = []
    for s in streams:
        cache = api.init_paged_cache(params, 1, 1 + MAX_PAGES, PAGE)
        table = jnp.arange(1, 1 + MAX_PAGES, dtype=jnp.int32)[None]
        for pos in range(s.shape[0]):
            lg, cache = api.paged_decode_step(
                params, cache, s[pos][None, None],
                jnp.full((1,), pos, jnp.int32), table)
        solo.append(np.asarray(lg[0, 0, :api.cfg.vocab]))

    B = len(streams)
    cache = api.init_paged_cache(params, B, 1 + B * MAX_PAGES, PAGE)
    table = _shuffled_table(B, seed=5)
    # stagger the slots so the batched run ends with ragged positions
    maxlen = max(s.shape[0] for s in streams)
    for step in range(maxlen):
        toks = np.zeros((B, 1), np.int32)
        positions = np.zeros((B,), np.int32)
        live = []
        for i, s in enumerate(streams):
            off = step - (maxlen - s.shape[0])   # slot i starts late
            if 0 <= off < s.shape[0]:
                toks[i, 0] = int(s[off])
                positions[i] = off
                live.append(i)
        lg, cache = api.paged_decode_step(
            params, cache, jnp.asarray(toks), jnp.asarray(positions), table)
        for i in live:
            if positions[i] == streams[i].shape[0] - 1:
                np.testing.assert_allclose(
                    np.asarray(lg[i, 0, :api.cfg.vocab]), solo[i],
                    atol=1e-5, rtol=1e-5, err_msg=f"slot {i}")


# -- page allocator -----------------------------------------------------------

def test_page_allocator_never_hands_out_scratch():
    a = PageAllocator(5)
    got = sorted(a.alloc() for _ in range(4))
    assert got == [1, 2, 3, 4]
    with pytest.raises(OutOfPages):
        a.alloc()
    a.free([2, 4])
    assert a.free_pages == 2 and a.alloc() in (2, 4)


# -- engine scheduling --------------------------------------------------------

def _isolated(api, params, prompt, max_new):
    e = ServeEngine(api, params, n_slots=1, page_size=PAGE, max_len=BUF)
    r = e.submit(prompt, max_new)
    e.run()
    return list(r.generated)


@pytest.mark.parametrize("family", ["dense", "ssm"])
def test_engine_midflight_join_matches_isolated(family, request):
    """Requests joining a RUNNING batch (slot recycling, no retrace) decode
    exactly the tokens they would get alone.  Dense + ssm (the ssm case
    pins recurrent per-slot state across recycles); MoE is excluded on
    purpose: capacity-factor routing is batch-composition-dependent."""
    api, params = request.getfixturevalue(family)
    rng = np.random.default_rng(0)
    jobs = [(rng.integers(1, api.cfg.vocab, n).tolist(), m)
            for n, m in ((3, 5), (7, 3), (1, 6), (5, 4), (2, 5))]
    expect = [_isolated(api, params, p, m) for p, m in jobs]

    eng = ServeEngine(api, params, n_slots=2, page_size=PAGE, max_len=BUF)
    eng.warmup()
    reqs = [eng.submit(p, m) for p, m in jobs]
    eng.run()
    assert [list(r.generated) for r in reqs] == expect
    # every page returned on eviction; slots reused across 5 jobs on 2 slots
    assert eng.alloc.free_pages == eng.n_pages - 1
    assert all(s.state == "free" for s in eng.slots)


@pytest.mark.parametrize("family", ["dense", "ssm"])
def test_engine_stall_on_page_exhaustion_recovers(family, request):
    """A pool too small for both slots stalls one mid-flight; it must
    resume after an eviction and still decode the isolated tokens.  The
    ssm case pins that a stalled slot's recurrent state is frozen (no
    spurious token-0 advance) while it waits."""
    api, params = request.getfixturevalue(family)
    rng = np.random.default_rng(1)
    p0, p1 = (rng.integers(1, api.cfg.vocab, n).tolist() for n in (3, 7))
    expect = [_isolated(api, params, p0, 5), _isolated(api, params, p1, 3)]
    eng = ServeEngine(api, params, n_slots=2, page_size=PAGE, max_len=BUF,
                      n_pages=4)   # 3 real pages < 2 + 3 needed at once
    r0, r1 = eng.submit(p0, 5), eng.submit(p1, 3)
    eng.run()
    assert eng.stall_events > 0
    assert [list(r0.generated), list(r1.generated)] == expect


def test_engine_idle_slot_then_late_join_ssm(ssm):
    """A FREE slot idling alongside a running one must not accumulate
    recurrent state: a request admitted into it later decodes exactly the
    tokens it would get alone.  Regression for the unmasked paged step,
    which advanced mamba/xLSTM state for EVERY slot each step — token-0
    feeds polluted idle slots between eviction and the next admission."""
    api, params = ssm
    rng = np.random.default_rng(7)
    pa = rng.integers(1, api.cfg.vocab, 4).tolist()   # long-runner
    pb = rng.integers(1, api.cfg.vocab, 2).tolist()   # finishes early
    pc = rng.integers(1, api.cfg.vocab, 3).tolist()   # late joiner
    expect = [_isolated(api, params, p, m)
              for p, m in ((pa, 8), (pb, 2), (pc, 4))]

    eng = ServeEngine(api, params, n_slots=2, page_size=PAGE, max_len=BUF)
    eng.warmup()
    ra, rb = eng.submit(pa, 8), eng.submit(pb, 2)
    while not rb.done:
        eng.step()
    # rb's slot is now FREE with an empty queue: it rides along idle for a
    # few steps while ra keeps decoding (the pollution window), then rc is
    # admitted into the recycled slot mid-flight
    for _ in range(3):
        eng.step()
    rc = eng.submit(pc, 4)
    eng.run()
    assert [list(r.generated) for r in (ra, rb, rc)] == expect


def test_engine_all_slots_stalled_raises_out_of_pages(dense):
    """When every active slot is stalled on an exhausted pool no eviction
    can ever free a page again — the engine must fail fast with OutOfPages
    instead of busy-spinning no-op device steps into the wedge assert."""
    api, params = dense
    eng = ServeEngine(api, params, n_slots=2, page_size=PAGE, max_len=BUF,
                      n_pages=2)            # one real page for two slots
    eng.submit([1, 2], 6)                   # each needs 2 pages to finish
    eng.submit([3, 4], 6)
    with pytest.raises(OutOfPages, match="deadlock"):
        eng.run()


@pytest.mark.parametrize("arch", ["xlstm-350m",        # mlstm + slstm
                                  "jamba-v0.1-52b"])   # hybrid: mamba
def test_paged_decode_advance_mask_freezes_recurrent_state(arch):
    """advance=False slots keep every recurrent (non-paged) cache leaf
    bitwise unchanged through a fused step; advance=True slots move."""
    api, params = _model(arch)
    B = 2
    cache = api.init_paged_cache(params, B, 1 + B * MAX_PAGES, PAGE)
    table = _shuffled_table(B)

    def recurrent_leaves(c):
        out = {}

        def leaf(path, x):
            if not any(getattr(p, "key", None) in ("k_pages", "v_pages")
                       for p in path):
                out[jax.tree_util.keystr(path)] = np.asarray(x)
            return x

        jax.tree_util.tree_map_with_path(leaf, c)
        return out

    before = recurrent_leaves(cache)
    assert before, "no recurrent leaves found — wrong arch for this test"
    key = jax.random.PRNGKey(4)
    mask = jnp.array([True, False])          # slot 1 frozen
    for pos in range(3):
        toks = jax.random.randint(jax.random.fold_in(key, pos), (B, 1), 0,
                                  api.cfg.vocab, jnp.int32)
        _, cache = api.paged_decode_step(
            params, cache, toks, jnp.full((B,), pos, jnp.int32), table, mask)
    after = recurrent_leaves(cache)
    moved = 0
    for k in before:                         # leaves are (periods, slot, ...)
        np.testing.assert_array_equal(after[k][:, 1], before[k][:, 1],
                                      err_msg=f"frozen slot drifted: {k}")
        moved += int(not np.array_equal(after[k][:, 0], before[k][:, 0]))
    assert moved > 0, "advancing slot's recurrent state never changed"


def test_engine_static_admission_blocks_head_of_line(dense):
    """Static mode admits only full batches: the second wave must not start
    before the first fully drains (the baseline's defining behavior)."""
    api, params = dense
    eng = ServeEngine(api, params, n_slots=2, page_size=PAGE, max_len=BUF,
                      admission="static")
    short = eng.submit([5], 2)       # finishes fast...
    long = eng.submit([5, 6, 7], 6)  # ...but its slot idles until this ends
    late = eng.submit([9], 2)
    eng.run()
    assert all(r.done for r in (short, long, late))
    # head-of-line blocking: the late request could not start before the
    # long one finished, even though short's slot was free much earlier
    assert late.first_token_step > long.finish_step - 1


def test_engine_eos_evicts_early(dense):
    api, params = dense
    prompt = [3, 1, 4]
    full = _isolated(api, params, prompt, 6)
    eng = ServeEngine(api, params, n_slots=2, page_size=PAGE, max_len=BUF)
    r = eng.submit(prompt, 6, eos_id=full[1])
    eng.run()
    assert r.generated == full[:2] and r.done
    assert eng.alloc.free_pages == eng.n_pages - 1


def test_engine_rejects_oversized_request(dense):
    api, params = dense
    eng = ServeEngine(api, params, n_slots=1, page_size=PAGE, max_len=BUF)
    with pytest.raises(AssertionError, match="max_len"):
        eng.submit(list(range(1, BUF)), 2)


# -- consensus bridge ---------------------------------------------------------

def test_bridge_staleness_and_divergence(dense):
    from repro.core import AlgoConfig, MultiLearnerTrainer
    from repro.models.model import make_synthetic_batch
    from repro.optim import sgd

    api, params = dense
    n = 4
    tr = MultiLearnerTrainer(
        api.loss_fn, sgd(0.05),
        AlgoConfig(algo="dpsgd", topology="ring", n_learners=n),
        engine="flat")
    key = jax.random.PRNGKey(0)
    st = tr.init(key, params)

    def batch(i):
        b = make_synthetic_batch(api.cfg, jax.random.PRNGKey(i), n * 2, 16)
        return jax.tree_util.tree_map(
            lambda x: x.reshape((n, 2) + x.shape[1:]), b)

    for i in range(2):
        st, _ = tr.train_step(st, batch(i))
    bridge = ConsensusBridge(tr)
    snap = bridge.snapshot(st)
    assert snap.step == 2 and snap.consensus_dist >= 0

    # serve from the snapshot while training keeps moving
    eng = ServeEngine(api, snap.params, n_slots=2, page_size=PAGE,
                      max_len=BUF)
    r = eng.submit([5, 9, 3], 3)
    for i in range(2, 5):
        st, _ = tr.train_step(st, batch(i))
        if eng.has_work:
            eng.step()
    eng.run()
    assert r.done and len(r.generated) == 3

    stale = bridge.staleness(st, snap)
    assert stale["steps_behind"] == 3
    assert stale["consensus_dist_now"] >= 0

    live = bridge.snapshot(st)
    div = served_divergence(api, snap.params, live.params,
                            np.array([[5, 9, 3, 1]]))
    assert 0.0 <= div["top1_agreement"] <= 1.0
    assert div["max_abs_logit_diff"] >= div["mean_abs_logit_diff"] >= 0
    eng.set_params(live.params)   # hot-swap must not raise (no retrace)
