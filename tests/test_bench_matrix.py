"""Unit tests for the benchmark-matrix harness (ISSUE 6 / DESIGN §13):
spec expansion, the schema-versioned BENCH_PR<N>.json record, the legacy
BENCH_PR3.json adapter, the cross-PR trajectory classifier, and the
check_regression CLI's failure exit codes.

Everything here runs on synthetic payloads — no jax, no training; the
schema/trajectory/check_regression modules are deliberately importable
without the training stack and these tests keep them that way.
"""
import json
import os

import pytest

from benchmarks import check_regression, schema, trajectory
from benchmarks.matrix import REGISTRY, SPEC, MatrixSpec, expand

HISTORY_PR3 = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "benchmarks", "history", "BENCH_PR3.json")


# -- helpers ------------------------------------------------------------------

def axes(**over):
    base = {"workload": "throughput", "model": "fcnet", "algo": "dpsgd",
            "topology": "random_pair", "n": 5, "precision": "f32",
            "engine": "flat"}
    base.update(over)
    return base


def payload(pr, cells):
    p = schema.new_payload(pr)
    for ax, metrics in cells:
        key, cell = schema.make_cell(ax, metrics)
        p["cells"][key] = cell
    return p


# -- spec expansion -----------------------------------------------------------

TINY = MatrixSpec(
    base={"model": ("fcnet",), "precision": ("f32",), "n": (5,)},
    workloads={"wl": {"algo": ("a", "b"), "engine": ("flat", "pytree"),
                      "topology": ("ring",)}},
    exclude=({"algo": "b", "engine": "flat"},),
    smoke={"wl": {"algo": ("a",)}},
)


def test_expand_cartesian_product_minus_excludes():
    cells = expand(TINY)
    assert len(cells) == 3   # 2 algos x 2 engines - 1 excluded
    assert {(c["algo"], c["engine"]) for c in cells} == {
        ("a", "flat"), ("a", "pytree"), ("b", "pytree")}
    assert all(c["workload"] == "wl" and c["n"] == 5 for c in cells)


def test_expand_deterministic_order():
    assert expand(TINY) == expand(TINY)
    assert [tuple(c.items()) for c in expand(TINY)] == \
        [tuple(c.items()) for c in expand(TINY)]


def test_expand_smoke_subsets_values_keeps_keys():
    smoke = expand(TINY, smoke=True)
    assert {(c["algo"], c["engine"]) for c in smoke} == {
        ("a", "flat"), ("a", "pytree")}
    full_keys = {schema.cell_key(c) for c in expand(TINY)}
    assert {schema.cell_key(c) for c in smoke} <= full_keys


def test_default_spec_covers_registry_and_excludes_ssgd_star_flat():
    cells = expand(SPEC)
    assert {c["workload"] for c in cells} == set(REGISTRY)
    assert not any(c["algo"] == "ssgd_star" and c["engine"] == "flat"
                   for c in cells)
    assert any(c["algo"] == "ssgd_star" for c in cells)
    # smoke trims values, never introduces new cells
    assert {schema.cell_key(c) for c in expand(SPEC, smoke=True)} <= \
        {schema.cell_key(c) for c in cells}


# -- cell keys ----------------------------------------------------------------

def test_cell_key_stability_pin():
    # the cross-PR contract: this exact string is what aligns trajectories
    assert schema.cell_key(axes()) == (
        "workload=throughput/model=fcnet/algo=dpsgd/topology=random_pair/"
        "n=5/precision=f32/engine=flat")


def test_cell_key_order_independent_and_extra_axes_sorted():
    a = axes()
    shuffled = dict(reversed(list(a.items())))
    assert schema.cell_key(a) == schema.cell_key(shuffled)
    with_extra = axes(zeta=1, batch_scale=4)
    assert schema.cell_key(with_extra).endswith(
        "engine=flat/batch_scale=4/zeta=1")


def test_cell_key_missing_axis_raises():
    a = axes()
    del a["precision"]
    with pytest.raises(schema.SchemaError, match="precision"):
        schema.cell_key(a)


# -- schema validation --------------------------------------------------------

def test_validate_good_payload():
    p = payload(6, [(axes(), {"us_per_step": 10.0})])
    assert schema.validate(p) == []


def test_validate_rejects_unknown_version():
    p = payload(6, [(axes(), {"us_per_step": 10.0})])
    p["schema_version"] = 99
    errs = schema.validate(p)
    assert len(errs) == 1 and "unknown schema_version" in errs[0]


def test_validate_rejects_missing_fields():
    p = payload(6, [(axes(), {"us_per_step": 10.0})])
    key = next(iter(p["cells"]))
    del p["cells"][key]["metrics"]
    assert any("metrics" in e for e in schema.validate(p))

    p2 = payload(6, [(axes(), {"us_per_step": 10.0})])
    del p2["pr"]
    assert any("pr" in e for e in schema.validate(p2))

    p3 = payload(6, [(axes(), {"us_per_step": 10.0})])
    p3["cells"] = {}
    assert any("cells" in e for e in schema.validate(p3))


def test_validate_rejects_key_axes_mismatch_and_bad_metrics(tmp_path):
    p = payload(6, [(axes(), {"us_per_step": 10.0})])
    key = next(iter(p["cells"]))
    p["cells"]["bogus/key"] = p["cells"].pop(key)
    assert any("does not match its axes" in e for e in schema.validate(p))

    p2 = payload(6, [(axes(), {"us_per_step": "fast"})])
    assert any("non-numeric" in e for e in schema.validate(p2))

    path = tmp_path / "BENCH_PR9.json"
    path.write_text(json.dumps(p))
    with pytest.raises(schema.SchemaError):
        schema.load_result(str(path))


# -- legacy adapter (backward compat with the pre-matrix BENCH_PR3.json) ------

LEGACY = {
    "config": {"n_learners": 5, "local_batch": 400, "n_elem": 42_310},
    "algos": {
        "dpsgd": {"pytree_us_per_step": 100.0, "flat_us_per_step": 95.0,
                  "flat_speedup": 1.05, "flat_over_pytree_ratio": 0.95,
                  "tokens_per_s_pytree": 2e4, "tokens_per_s_flat": 2.1e4,
                  "flat_step_max_concat_elems": 12,
                  "fused_kernel": True, "default_engine_flat": True},
    },
}


def test_legacy_adapter_synthetic(tmp_path):
    path = tmp_path / "BENCH_PR3.json"
    path.write_text(json.dumps(LEGACY))
    p = schema.load_result(str(path))
    assert p["pr"] == 3 and p["legacy"] is True
    assert schema.validate(p) == []
    key = schema.cell_key(axes())
    assert p["cells"][key]["metrics"] == {"us_per_step": 95.0,
                                          "tokens_per_s": 2.1e4}
    assert p["cells"][key]["extra"]["fused_kernel"] is True
    tree_key = schema.cell_key(axes(engine="pytree"))
    assert p["cells"][tree_key]["metrics"]["us_per_step"] == 100.0


def test_legacy_adapter_needs_pr_number_in_filename(tmp_path):
    path = tmp_path / "bench.json"
    path.write_text(json.dumps(LEGACY))
    with pytest.raises(schema.SchemaError, match="PR number"):
        schema.load_result(str(path))


def test_committed_bench_pr3_parses_under_new_loader():
    """The real pre-matrix artifact must never be orphaned by the schema."""
    p = schema.load_result(HISTORY_PR3)
    assert p["pr"] == 3 and p.get("legacy")
    assert schema.validate(p) == []
    # one flat + one pytree cell per measured algorithm
    engines = {}
    for cell in p["cells"].values():
        engines.setdefault(cell["axes"]["algo"], set()).add(
            cell["axes"]["engine"])
    assert engines.keys() >= {"ssgd", "dpsgd", "adpsgd"}
    assert all(v == {"flat", "pytree"} for v in engines.values())
    assert all(c["metrics"]["us_per_step"] > 0 for c in p["cells"].values())


# -- trajectory ---------------------------------------------------------------

def test_trajectory_improvement_ok_and_new_removed_cells():
    p3 = payload(3, [(axes(), {"us_per_step": 100.0}),
                     (axes(algo="ssgd"), {"us_per_step": 50.0}),
                     (axes(algo="adpsgd"), {"us_per_step": 80.0})])
    p6 = payload(6, [(axes(), {"us_per_step": 60.0}),          # improved
                     (axes(algo="ssgd"), {"us_per_step": 55.0}),  # ok
                     (axes(algo="gone"), {"us_per_step": 9.0})])  # new
    rows = {r["key"]: r for r in trajectory.classify(
        trajectory.build_trajectory([p3, p6]), 6)}
    assert rows[schema.cell_key(axes())]["status"] == "improved"
    assert rows[schema.cell_key(axes())]["ratio"] == pytest.approx(0.6)
    assert rows[schema.cell_key(axes(algo="ssgd"))]["status"] == "ok"
    assert rows[schema.cell_key(axes(algo="gone"))]["status"] == "new"
    assert rows[schema.cell_key(axes(algo="adpsgd"))]["status"] == "removed"


def test_trajectory_regression_past_tolerance_gates():
    p3 = payload(3, [(axes(), {"us_per_step": 100.0})])
    p6 = payload(6, [(axes(), {"us_per_step": 100.0 * 2.5})])
    rows = trajectory.classify(trajectory.build_trajectory([p3, p6]), 6)
    assert rows[0]["status"] == "regression"
    # inside the default band -> ok
    p6b = payload(6, [(axes(), {"us_per_step": 150.0})])
    rows = trajectory.classify(trajectory.build_trajectory([p3, p6b]), 6)
    assert rows[0]["status"] == "ok"


def test_trajectory_per_cell_tolerance_override():
    p3 = payload(3, [(axes(), {"us_per_step": 100.0})])
    p6 = payload(6, [(axes(), {"us_per_step": 130.0})])
    key = next(iter(p6["cells"]))
    p6["cells"][key]["tolerance"] = 1.2   # tighter than the default band
    rows = trajectory.classify(trajectory.build_trajectory([p3, p6]), 6)
    assert rows[0]["status"] == "regression"
    assert rows[0]["tolerance"] == 1.2


def test_trajectory_uses_last_two_appearances():
    p3 = payload(3, [(axes(), {"us_per_step": 1000.0})])
    p5 = payload(5, [(axes(), {"us_per_step": 100.0})])
    p6 = payload(6, [(axes(), {"us_per_step": 101.0})])
    rows = trajectory.classify(
        trajectory.build_trajectory([p3, p5, p6]), 6)
    assert rows[0]["status"] == "ok"
    assert rows[0]["prs"] == [3, 5, 6]


def test_trajectory_cli_report_and_gate(tmp_path, monkeypatch, capsys):
    monkeypatch.setenv("REPRO_BENCH_RESULTS", str(tmp_path))
    p3 = payload(3, [(axes(), {"us_per_step": 100.0})])
    p6 = payload(6, [(axes(), {"us_per_step": 300.0}),
                     (axes(algo="ssgd"), {"us_per_step": 10.0})])
    for p in (p3, p6):
        (tmp_path / f"BENCH_PR{p['pr']}.json").write_text(json.dumps(p))
    glob = str(tmp_path / "BENCH_PR*.json")
    assert trajectory.main([glob]) == 0            # report never gates
    assert (tmp_path / "trajectory.csv").exists()
    assert trajectory.main([glob, "--gate"]) == 1  # 3x past the band
    assert trajectory.main([glob, "--gate", "--tolerance", "4.0"]) == 0
    out = capsys.readouterr()
    assert "regression" in out.out + out.err


def test_trajectory_cli_needs_two_prs(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_BENCH_RESULTS", str(tmp_path))
    p6 = payload(6, [(axes(), {"us_per_step": 10.0})])
    (tmp_path / "BENCH_PR6.json").write_text(json.dumps(p6))
    assert trajectory.main([str(tmp_path / "BENCH_PR*.json")]) == 2


def test_trajectory_results_shadow_history_on_same_pr(tmp_path):
    hist = tmp_path / "hist"
    res = tmp_path / "res"
    hist.mkdir(), res.mkdir()
    stale = payload(3, [(axes(), {"us_per_step": 999.0})])
    fresh = payload(3, [(axes(), {"us_per_step": 100.0})])
    (hist / "BENCH_PR3.json").write_text(json.dumps(stale))
    (res / "BENCH_PR3.json").write_text(json.dumps(fresh))
    loaded = trajectory.load_payloads([str(res / "BENCH_PR*.json"),
                                       str(hist / "BENCH_PR*.json")])
    assert len(loaded) == 1
    assert next(iter(loaded[0]["cells"].values()))[
        "metrics"]["us_per_step"] == 100.0


# -- check_regression CLI: files, globs, exit codes ---------------------------

def _write_legacy(tmp_path, ratio=0.95, fused=True, concat=12, pr=3):
    data = json.loads(json.dumps(LEGACY))
    a = data["algos"]["dpsgd"]
    a["flat_over_pytree_ratio"] = ratio
    a["flat_us_per_step"] = 100.0 * ratio
    a["flat_speedup"] = 1.0 / ratio
    a["fused_kernel"] = fused
    a["flat_step_max_concat_elems"] = concat
    path = tmp_path / f"BENCH_PR{pr}.json"
    path.write_text(json.dumps(data))
    return path


def test_check_regression_ok_and_explicit_path(tmp_path):
    path = _write_legacy(tmp_path)
    assert check_regression.main([str(path)]) == 0


def test_check_regression_default_path_missing_exits_2(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_BENCH_RESULTS", str(tmp_path / "empty"))
    assert check_regression.main() == 2
    assert check_regression.main([str(tmp_path / "nope.json")]) == 2


def test_check_regression_unmatched_glob_exits_2(tmp_path):
    assert check_regression.main([str(tmp_path / "BENCH_PR*.json")]) == 2


def test_check_regression_bad_json_exits_2(tmp_path):
    path = tmp_path / "BENCH_PR3.json"
    path.write_text("{not json")
    assert check_regression.main([str(path)]) == 2


def test_check_regression_legacy_violations_exit_1(tmp_path, capsys):
    slow = _write_legacy(tmp_path, ratio=1.5)
    assert check_regression.main([str(slow)]) == 1
    assert "SLOWER" in capsys.readouterr().err

    unfused = _write_legacy(tmp_path, fused=False)
    assert check_regression.main([str(unfused)]) == 1
    assert "fused" in capsys.readouterr().err

    refatten = _write_legacy(tmp_path, concat=42_310)
    assert check_regression.main([str(refatten)]) == 1
    assert "concatenate" in capsys.readouterr().err


def test_check_regression_matrix_gate_over_glob(tmp_path):
    _write_legacy(tmp_path, pr=3)   # flat dpsgd at 95 us/step
    p6 = payload(6, [(axes(), {"us_per_step": 95.0 * 3})])
    (tmp_path / "BENCH_PR6.json").write_text(json.dumps(p6))
    # cross-PR cell regressed 3x -> gate fails on the glob...
    assert check_regression.main([str(tmp_path / "BENCH_PR*.json")]) == 1
    # ...but each file alone still passes its own static contract
    assert check_regression.main([str(tmp_path / "BENCH_PR6.json")]) == 0
    p6_ok = payload(6, [(axes(), {"us_per_step": 96.0})])
    (tmp_path / "BENCH_PR6.json").write_text(json.dumps(p6_ok))
    assert check_regression.main([str(tmp_path / "BENCH_PR*.json")]) == 0
