"""AD-PSGD (async staleness-bounded gossip) + DecentLaM invariants.

Covers the tentpole contracts:
  * staleness bound 0  ==> bitwise-identical to synchronous pairwise DPSGD
  * injected straggler ==> bounded staleness, lagging clock, still converges
  * DecentLaM          ==> heavy-ball when gossip is off (bitwise); removes
                           the naive-momentum fixed-point bias under gossip
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import AlgoConfig, MultiLearnerTrainer
from repro.core.dpsgd import mix_pair_gather, straggler_active_mask
from repro.core.topology import pair_partners
from repro.optim import decentlam, sgd


def _quad_loss(p, b):
    return jnp.mean((b["x"] @ p["w"] - b["y"]) ** 2) \
        + 0.01 * jnp.sum(p["w"] ** 4)


def _quad_batch(n, key=1):
    return {"x": jax.random.normal(jax.random.PRNGKey(key), (n, 16, 8)),
            "y": jax.random.normal(jax.random.PRNGKey(key + 1), (n, 16, 3))}


def _run(cfg, opt, steps, key=0, loss_fn=_quad_loss, params=None, batch=None):
    params = params or {"w": jax.random.normal(jax.random.PRNGKey(9),
                                               (8, 3)) * 0.1}
    batch = batch or _quad_batch(cfg.n_learners)
    tr = MultiLearnerTrainer(loss_fn, opt, cfg, alpha_for_diag=0.05)
    st = tr.init(jax.random.PRNGKey(key), params)
    metrics = []
    for _ in range(steps):
        st, m = tr.train_step(st, batch)
        metrics.append(m)
    return st, metrics, tr


# ---------------------------------------------------------------------------
# gossip primitives
# ---------------------------------------------------------------------------

def test_pair_partners_is_involution():
    for seed in range(5):
        for n in (2, 5, 8, 16):
            p = np.asarray(pair_partners(jax.random.PRNGKey(seed), n))
            assert (p[p] == np.arange(n)).all()        # partner-of-partner
            assert ((p != np.arange(n)).sum() >= (n // 2) * 2 - 2)


def test_mix_pair_gather_matches_matrix():
    """Gather form == 0.5(I+P) einsum form of the same matching."""
    from repro.core import mix_einsum
    from repro.core.topology import random_pair_matrix
    n = 8
    key = jax.random.PRNGKey(4)
    t = {"w": jax.random.normal(jax.random.PRNGKey(5), (n, 6))}
    out_g = mix_pair_gather(t, pair_partners(key, n))
    out_m = mix_einsum(t, random_pair_matrix(key, n))
    np.testing.assert_allclose(np.asarray(out_g["w"]),
                               np.asarray(out_m["w"]), atol=1e-6)


def test_mix_pair_gather_solo_untouched():
    """Odd n: the unmatched learner must keep its weights bitwise, even when
    the remote buffer differs from the live weights."""
    n = 5
    key = jax.random.PRNGKey(0)
    partner = pair_partners(key, n)
    solo = int(np.where(np.asarray(partner) == np.arange(n))[0][0])
    t = {"w": jax.random.normal(jax.random.PRNGKey(1), (n, 4))}
    stale = {"w": jnp.zeros_like(t["w"])}
    out = mix_pair_gather(t, partner, remote=stale)
    np.testing.assert_array_equal(np.asarray(out["w"][solo]),
                                  np.asarray(t["w"][solo]))


def test_straggler_active_mask():
    n = 4
    m0 = straggler_active_mask(jnp.asarray(0), n, 0, 3)
    m1 = straggler_active_mask(jnp.asarray(1), n, 0, 3)
    assert bool(m0[0]) and not bool(m1[0])
    assert np.asarray(m1)[1:].all()
    assert np.asarray(straggler_active_mask(jnp.asarray(1), n, -1, 3)).all()


# ---------------------------------------------------------------------------
# AD-PSGD semantics
# ---------------------------------------------------------------------------

def test_staleness_zero_matches_sync_pairwise_dpsgd_bitwise():
    """Acceptance contract: AD-PSGD with staleness bound 0 and no straggler
    IS synchronous pairwise DPSGD, bit for bit, optimizer state included."""
    n, steps = 8, 12
    sync = AlgoConfig(algo="dpsgd", topology="random_pair", n_learners=n)
    adp = AlgoConfig(algo="adpsgd", topology="random_pair", n_learners=n,
                     max_staleness=0)
    opt = sgd(0.05, momentum=0.9)
    st_s, _, tr_s = _run(sync, opt, steps)
    st_a, _, tr_a = _run(adp, opt, steps)
    # both run the flat fused engine by default; the raw (n, T, 128) buffers
    # must agree bit for bit, and so must the pytree views
    np.testing.assert_array_equal(np.asarray(st_s.params),
                                  np.asarray(st_a.params))
    vs, va = tr_s.state_view(st_s), tr_a.state_view(st_a)
    np.testing.assert_array_equal(np.asarray(vs.params["w"]),
                                  np.asarray(va.params["w"]))
    np.testing.assert_array_equal(np.asarray(vs.opt_state["mu"]["w"]),
                                  np.asarray(va.opt_state["mu"]["w"]))
    assert int(jnp.max(st_a.age)) == 0


def test_straggler_lags_clock_and_creates_bounded_staleness():
    n, slow, tau = 8, 4, 6
    cfg = AlgoConfig(algo="adpsgd", n_learners=n, max_staleness=tau,
                     slow_learner=0, slow_factor=slow)
    st, metrics, tr = _run(cfg, sgd(0.05), steps=13)
    clock = np.asarray(st.clock)
    # 13 ticks: straggler completed ceil(13/4)=4 steps, everyone else 13
    assert clock[0] == 4 and (clock[1:] == 13).all()
    stale_max = max(float(m.staleness_max) for m in metrics)
    assert 0 < stale_max <= tau
    # the bound holds on the state too, at every observable point
    assert int(jnp.max(st.age)) <= tau


def test_staleness_bound_forces_publish():
    """tau=1: partners may never see a buffer older than 1 tick even with a
    very slow straggler."""
    cfg = AlgoConfig(algo="adpsgd", n_learners=4, max_staleness=1,
                     slow_learner=0, slow_factor=10)
    st, metrics, _ = _run(cfg, sgd(0.05), steps=20)
    assert max(float(m.staleness_max) for m in metrics) <= 1.0


def test_adpsgd_converges_with_straggler():
    """Convergence parity: staleness + a 3x straggler should not destroy
    training on the quadratic task (same order of final loss as sync)."""
    n = 8
    sync = AlgoConfig(algo="dpsgd", topology="random_pair", n_learners=n)
    adp = AlgoConfig(algo="adpsgd", n_learners=n, max_staleness=4,
                     slow_learner=0, slow_factor=3)
    opt = sgd(0.05, momentum=0.9)
    _, m_s, _ = _run(sync, opt, steps=150)
    _, m_a, _ = _run(adp, opt, steps=150)
    f_s = float(m_s[-1].loss)
    f_a = float(m_a[-1].loss)
    assert np.isfinite(f_a)
    assert f_a < 2.0 * f_s + 0.05, (f_a, f_s)


def test_adpsgd_diagnostics_report_staleness():
    n = 4
    cfg = AlgoConfig(algo="adpsgd", n_learners=n, max_staleness=8,
                     slow_learner=0, slow_factor=3)
    st, _, tr = _run(cfg, sgd(0.05), steps=5)   # tick 5: straggler age == 2
    d = tr.diagnostics(st, _quad_batch(n))
    assert float(d.staleness_max) == float(jnp.max(st.age))
    assert float(d.staleness_mean) == float(jnp.mean(st.age.astype(jnp.float32)))
    np.testing.assert_allclose(float(d.consensus_dist),
                               float(jnp.sqrt(d.sigma_w_sq)), rtol=1e-6)


def test_adpsgd_config_validation():
    with pytest.raises(AssertionError):
        AlgoConfig(algo="adpsgd", topology="ring")
    with pytest.raises(AssertionError):
        AlgoConfig(algo="adpsgd", max_staleness=-1)
    with pytest.raises(AssertionError):
        AlgoConfig(algo="adpsgd", slow_learner=99, n_learners=4)


# ---------------------------------------------------------------------------
# DecentLaM
# ---------------------------------------------------------------------------

def test_decentlam_equals_heavy_ball_without_gossip():
    """solo topology => mix(w) == w => DecentLaM must be bitwise SGD+momentum."""
    cfg = AlgoConfig(algo="dpsgd", topology="solo", n_learners=4)
    st_hb, _, tr_hb = _run(cfg, sgd(0.05, momentum=0.9), steps=10)
    st_dl, _, tr_dl = _run(cfg, decentlam(0.05, momentum=0.9), steps=10)
    np.testing.assert_array_equal(
        np.asarray(tr_hb.params_tree(st_hb)["w"]),
        np.asarray(tr_dl.params_tree(st_dl)["w"]))


def test_decentlam_removes_momentum_bias():
    """Heterogeneous-curvature quadratic on a ring (the DecentLaM paper's
    failure mode for naive momentum): f_j(w) = 0.5 a_j ||w - c_j||^2 with
    spread-out a_j.  Naive heavy-ball DPSGD parks the average model at a
    biased fixed point; DecentLaM lands on the momentum-free fixed point."""
    n, d = 8, 8
    cs = jax.random.normal(jax.random.PRNGKey(3), (n, d)) * 2.0
    a = jnp.linspace(0.2, 1.8, n)
    w_star = np.asarray((a[:, None] * cs).sum(0) / a.sum())

    def loss_fn(p, b):
        return 0.5 * jnp.mean(b["a"]) * jnp.mean(
            jnp.sum((p["w"][None] - b["c"]) ** 2, -1))

    batch = {"c": jnp.repeat(cs[:, None], 4, 1),
             "a": jnp.repeat(a[:, None], 4, 1)}
    params = {"w": jnp.zeros((d,))}
    cfg = AlgoConfig(algo="dpsgd", topology="ring", n_learners=n)

    def bias(opt):
        st, _, tr = _run(cfg, opt, steps=600, loss_fn=loss_fn, params=params,
                        batch=batch)
        wbar = np.asarray(jnp.mean(tr.params_tree(st)["w"], 0))
        return float(np.linalg.norm(wbar - w_star))

    lr = 0.2
    b_naive = bias(sgd(lr, momentum=0.9))
    b_dlam = bias(decentlam(lr, momentum=0.9))
    b_plain = bias(sgd(lr))
    assert b_naive > 1.5 * b_dlam, (b_naive, b_dlam)
    np.testing.assert_allclose(b_dlam, b_plain, rtol=1e-3)


def test_decentlam_trains_through_adpsgd():
    """Time-varying matchings need the damped drift (see optim/decentlam.py):
    with drift_scale = 1 - momentum the async path trains stably."""
    cfg = AlgoConfig(algo="adpsgd", n_learners=8, max_staleness=4,
                     slow_learner=0, slow_factor=3)
    _, metrics, _ = _run(cfg, decentlam(0.05, momentum=0.9, drift_scale=0.1),
                         steps=150)
    first, last = float(metrics[0].loss), float(metrics[-1].loss)
    assert np.isfinite(last) and last < first


def test_decentlam_exact_drift_unstable_on_switching_topology():
    """Documents WHY the guard exists: the paper-exact correction diverges
    under per-step random matchings (static-W assumption violated).  The
    trainer now refuses this pairing outright, so demonstrating the
    divergence requires the explicit ``unsafe_switching`` opt-out."""
    cfg = AlgoConfig(algo="dpsgd", topology="random_pair", n_learners=8)
    _, m_exact, _ = _run(cfg, decentlam(0.05, momentum=0.9,
                                        unsafe_switching=True), steps=150)
    _, m_damped, _ = _run(cfg, decentlam(0.05, momentum=0.9, drift_scale=0.1),
                          steps=150)
    last_exact = float(m_exact[-1].loss)
    last_damped = float(m_damped[-1].loss)
    assert np.isfinite(last_damped) and last_damped < float(m_damped[0].loss)
    assert (not np.isfinite(last_exact)) or last_exact > 2 * last_damped


def test_decentlam_exact_drift_refuses_time_varying_schedules():
    """The PR 1 divergence is no longer silent: an exact-drift DecentLaM
    (static_mixing_only) paired with ANY time-varying GossipSchedule —
    random matchings, multi-round matchings, one-peer exponential, AD-PSGD —
    raises at trainer construction; static schedules and the damped drift
    stay accepted, and so does the explicit unsafe override."""
    exact = decentlam(0.05, momentum=0.9)
    for cfg in (AlgoConfig(algo="dpsgd", topology="random_pair", n_learners=8),
                AlgoConfig(algo="dpsgd", topology="random_matching",
                           n_learners=8, gossip_rounds=2),
                AlgoConfig(algo="dpsgd", topology="one_peer_exp",
                           n_learners=8),
                AlgoConfig(algo="adpsgd", n_learners=8, max_staleness=2)):
        with pytest.raises(ValueError, match="time-varying"):
            MultiLearnerTrainer(_quad_loss, exact, cfg)
    # static schedules absorb the exact drift: accepted
    for topology in ("ring", "torus", "full", "hierarchical", "exp", "solo"):
        MultiLearnerTrainer(_quad_loss, exact,
                            AlgoConfig(algo="dpsgd", topology=topology,
                                       n_learners=8))
    # the damped drift is stable under switching: accepted
    MultiLearnerTrainer(
        _quad_loss, decentlam(0.05, momentum=0.9, drift_scale=0.1),
        AlgoConfig(algo="dpsgd", topology="random_pair", n_learners=8))
    # explicit opt-out for the divergence demonstration above
    MultiLearnerTrainer(
        _quad_loss, decentlam(0.05, momentum=0.9, unsafe_switching=True),
        AlgoConfig(algo="dpsgd", topology="random_pair", n_learners=8))
    # the guard survives optimizer wrappers (scale_by_schedule)
    from repro.optim import scale_by_schedule, constant_schedule
    with pytest.raises(ValueError, match="time-varying"):
        MultiLearnerTrainer(
            _quad_loss, scale_by_schedule(exact, constant_schedule(1.0)),
            AlgoConfig(algo="dpsgd", topology="random_pair", n_learners=8))


def test_decentlam_rejects_descend_then_mix():
    cfg = AlgoConfig(algo="dpsgd", gossip_order="descend_then_mix",
                     n_learners=4)
    with pytest.raises(ValueError):
        MultiLearnerTrainer(_quad_loss, decentlam(0.05), cfg)
