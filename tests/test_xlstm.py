import jax
import jax.numpy as jnp
import numpy as np

from repro.models.xlstm import (init_mlstm_cache, init_mlstm_params,
                                init_slstm_cache, init_slstm_params,
                                mlstm_block_decode, mlstm_block_forward,
                                mlstm_chunkwise, slstm_block_decode,
                                slstm_block_forward)


def _naive_mlstm(q, k, v, ig, fg):
    """Sequential stabilized mLSTM recurrence (ground truth)."""
    B, S, H, dh = q.shape
    C = jnp.zeros((B, H, dh, dh))
    n = jnp.zeros((B, H, dh))
    m = jnp.zeros((B, H))
    outs = []
    scale = dh ** -0.5
    for t in range(S):
        logf = jax.nn.log_sigmoid(fg[:, t])
        m_new = jnp.maximum(logf + m, ig[:, t])
        fp = jnp.exp(logf + m - m_new)
        ip = jnp.exp(ig[:, t] - m_new)
        C = fp[..., None, None] * C + ip[..., None, None] * \
            jnp.einsum("bhd,bhe->bhde", k[:, t], v[:, t])
        n = fp[..., None] * n + ip[..., None] * k[:, t]
        qt = q[:, t] * scale
        num = jnp.einsum("bhd,bhde->bhe", qt, C)
        den = jnp.abs(jnp.einsum("bhd,bhd->bh", qt, n))
        outs.append(num / jnp.maximum(den, jnp.exp(-m_new))[..., None])
        m = m_new
    return jnp.stack(outs, 1)


def test_mlstm_chunkwise_matches_sequential():
    B, S, H, dh = 1, 16, 2, 8
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (B, S, H, dh))
    k = jax.random.normal(ks[1], (B, S, H, dh))
    v = jax.random.normal(ks[2], (B, S, H, dh))
    ig = jax.random.normal(ks[3], (B, S, H))
    fg = jax.random.normal(ks[4], (B, S, H)) + 2.0
    out = mlstm_chunkwise(q * dh ** -0.5 / dh ** -0.5, k, v, ig, fg, chunk=4)
    # note: mlstm_chunkwise scales q internally
    ref = _naive_mlstm(q, k, v, ig, fg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


def test_mlstm_block_decode_matches_forward():
    d, H = 16, 2
    key = jax.random.PRNGKey(1)
    p = init_mlstm_params(key, d, H, jnp.float32)
    x = jax.random.normal(key, (1, 8, d))
    full = mlstm_block_forward(p, x, n_heads=H, chunk=4)
    cache = init_mlstm_cache(1, d, H)
    outs = []
    for t in range(8):
        o, cache = mlstm_block_decode(p, cache, x[:, t:t + 1], n_heads=H)
        outs.append(o)
    dec = jnp.concatenate(outs, 1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), atol=1e-3)


def test_slstm_block_decode_matches_forward():
    d, H = 16, 2
    key = jax.random.PRNGKey(2)
    p = init_slstm_params(key, d, H, jnp.float32)
    x = jax.random.normal(key, (1, 8, d))
    full = slstm_block_forward(p, x, n_heads=H, chunk=4)
    cache = init_slstm_cache(1, d, H)
    outs = []
    for t in range(8):
        o, cache = slstm_block_decode(p, cache, x[:, t:t + 1], n_heads=H)
        outs.append(o)
    dec = jnp.concatenate(outs, 1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), atol=1e-3)


def test_slstm_forward_finite_long():
    d, H = 32, 4
    p = init_slstm_params(jax.random.PRNGKey(3), d, H, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 64, d))
    y = slstm_block_forward(p, x, n_heads=H, chunk=16)
    assert bool(jnp.isfinite(y).all())
