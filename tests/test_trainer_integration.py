"""End-to-end behaviour: the paper's headline claim at test scale —
large-lr large-batch SSGD oscillates/diverges while DPSGD converges
(Fig. 2a) — plus the self-adjusting effective-learning-rate signature
(Fig. 2b).  Uses the uncentered TemplateImages task: whitened inputs do
NOT reproduce the separation (documented in EXPERIMENTS.md §Fig2)."""
import jax
import jax.numpy as jnp

from repro.core import AlgoConfig, MultiLearnerTrainer
from repro.data import ShardedLoader, TemplateImages
from repro.models import fcnet
from repro.optim import sgd

DS = TemplateImages()


def _setup(algo, lr, n=5, local=400, steps=150, seed=0, diag_at=()):
    loader = ShardedLoader(DS, n_learners=n, local_batch=local, seed=seed)
    key = jax.random.PRNGKey(seed)
    params = fcnet.init_params(key, in_dim=784, hidden=50)
    tr = MultiLearnerTrainer(fcnet.loss_fn, sgd(lr),
                             AlgoConfig(algo=algo, topology="random_pair",
                                        n_learners=n, noise_std=0.01),
                             alpha_for_diag=lr)
    st = tr.init(key, params)
    losses, diags = [], {}
    for i in range(steps):
        st, m = tr.train_step(st, loader.batch(i))
        losses.append(float(m.loss))
        if i in diag_at:
            diags[i] = tr.diagnostics(st, loader.batch(10_000 + i))
    return st, losses, tr, loader, diags


def test_fig2a_dpsgd_converges_where_ssgd_fails():
    """nB=2000, n=5 learners, 784-50-50-10 FC (the paper's MNIST setup),
    lr at the SSGD stability edge: DPSGD converges to ~0 loss, SSGD
    oscillates an order of magnitude higher."""
    lr = 0.5
    _, ssgd_losses, _, _, _ = _setup("ssgd", lr)
    _, dpsgd_losses, _, _, _ = _setup("dpsgd", lr)
    s = sum(ssgd_losses[-10:]) / 10
    d = sum(dpsgd_losses[-10:]) / 10
    assert d < 0.1, f"DPSGD failed to converge: {d}"
    assert s > 5 * d, f"SSGD unexpectedly stable: ssgd={s} dpsgd={d}"


def test_small_lr_parity():
    """At a safe lr both algorithms converge comparably (paper Tables 1/9:
    DPSGD matches SSGD when SSGD is stable)."""
    lr = 0.05
    _, ssgd_losses, _, _, _ = _setup("ssgd", lr, steps=80)
    _, dpsgd_losses, _, _, _ = _setup("dpsgd", lr, steps=80)
    assert abs(ssgd_losses[-1] - dpsgd_losses[-1]) < 0.5


def test_fig2b_effective_lr_self_adjusts():
    """alpha_e dips below alpha early (rough landscape, large sigma_w) and
    recovers later; sigma_w^2 shows the opposite trend (Fig. 2b)."""
    lr = 0.5
    st, _, tr, loader, diags = _setup("dpsgd", lr, steps=120,
                                      diag_at=(5, 119))
    early, late = diags[5], diags[119]
    assert float(early.alpha_e) < lr  # reduced while gradients are large
    assert float(late.alpha_e) > float(early.alpha_e) * 0.9
    # Delta2 (landscape noise) decays as training smooths the landscape
    assert float(late.delta_2) < float(early.delta_2)


def test_eval_uses_average_model():
    st, _, tr, loader, _ = _setup("dpsgd", 0.2, steps=10)
    ev = tr.eval_loss(st, loader.eval_batch(256))
    assert bool(jnp.isfinite(ev))
