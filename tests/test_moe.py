import jax
import jax.numpy as jnp
import numpy as np

from repro.models.moe import init_moe_params, moe_forward


def test_moe_shapes_and_finite():
    cfgs = [(8, 2), (4, 4), (16, 8)]
    key = jax.random.PRNGKey(0)
    for E, k in cfgs:
        p = init_moe_params(key, 32, 64, E, jnp.float32)
        x = jax.random.normal(key, (2, 16, 32))
        y, aux = moe_forward(p, x, n_experts=E, top_k=k, return_aux=True,
                             capacity_factor=2.0)
        assert y.shape == x.shape
        assert bool(jnp.isfinite(y).all())
        assert float(aux["dropped_frac"]) < 0.5


def test_moe_no_drops_with_big_capacity():
    key = jax.random.PRNGKey(1)
    p = init_moe_params(key, 16, 32, 4, jnp.float32)
    x = jax.random.normal(key, (1, 8, 16))
    _, aux = moe_forward(p, x, n_experts=4, top_k=2, capacity_factor=8.0,
                         return_aux=True)
    assert float(aux["dropped_frac"]) == 0.0


def test_moe_topk_equals_experts_is_dense_mixture():
    """k == E with huge capacity: every expert sees every token; output is
    the gate-weighted sum over ALL experts — check vs direct computation."""
    key = jax.random.PRNGKey(2)
    E, d, f = 4, 8, 16
    p = init_moe_params(key, d, f, E, jnp.float32)
    x = jax.random.normal(key, (1, 4, d))
    y = moe_forward(p, x, n_experts=E, top_k=E, capacity_factor=float(E + 1))
    xt = x.reshape(-1, d)
    gates = jax.nn.softmax(xt @ p["router"], -1)
    ref = jnp.zeros_like(xt)
    for e in range(E):
        h = jax.nn.silu(xt @ p["w1"][e]) * (xt @ p["w3"][e])
        ref += gates[:, e:e + 1] * (h @ p["w2"][e])
    np.testing.assert_allclose(np.asarray(y.reshape(-1, d)), np.asarray(ref),
                               atol=1e-4)


def test_moe_grads_flow_to_router():
    key = jax.random.PRNGKey(3)
    p = init_moe_params(key, 16, 32, 4, jnp.float32)
    x = jax.random.normal(key, (2, 8, 16))

    def loss(p_):
        return jnp.sum(moe_forward(p_, x, n_experts=4, top_k=2) ** 2)
    g = jax.grad(loss)(p)
    assert float(jnp.abs(g["router"]).sum()) > 0
    assert float(jnp.abs(g["w1"]).sum()) > 0
