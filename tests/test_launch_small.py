"""Small-mesh lowering tests (8 forced host devices, own subprocess so the
device count doesn't leak into the rest of the suite)."""
import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, re
from collections import Counter
import jax
from repro.configs import get_config
from repro.models.model import build_model
from repro.optim import sgd
from repro.launch import sharding as shd
from repro.launch.train import (jit_train_step, make_adpsgd_train_step,
                                make_dpsgd_train_step, make_ssgd_train_step,
                                make_decode_step, train_state_specs,
                                train_state_shardings)
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

mesh = jax.make_mesh((4, 2), ("data", "model"))
cfg = get_config("transformer-100m").smoke_config()
api = build_model(cfg)
opt = sgd(0.1, momentum=0.9)
out = {}
for algo, backend in [("dpsgd", "einsum"), ("dpsgd", "ppermute"),
                      ("ssgd", "einsum"), ("adpsgd", "ppermute")]:
    specs = train_state_specs(api, opt, mesh, algo=algo)
    shds = train_state_shardings(specs, mesh, algo=algo)
    bspecs = api.train_batch_spec(8, 64)
    bshd = shd.batch_sharding(bspecs, mesh, stacked=False)
    if algo == "dpsgd":
        step = make_dpsgd_train_step(api, opt, mesh, gossip_backend=backend)
    elif algo == "adpsgd":
        step = make_adpsgd_train_step(api, opt, mesh, max_staleness=4,
                                      slow_learner=0, slow_factor=3)
    else:
        step = make_ssgd_train_step(api, opt, mesh)
    with mesh:
        compiled = jit_train_step(
            step, in_shardings=shd.named_shardings((shds, bshd), mesh),
            out_shardings=shd.named_shardings((shds, None), mesh),
        ).lower(specs, bspecs).compile()
    colls = Counter(re.findall(
        r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)\(",
        compiled.as_text()))
    out[f"{algo}_{backend}"] = dict(colls)
    if algo == "adpsgd":
        # acceptance: the async path must actually TRAIN under pjit, not
        # just compile — run 4 real ticks and watch state/metrics evolve
        import numpy as np
        key = jax.random.PRNGKey(0)
        params = jax.vmap(lambda k: api.init(k))(
            jax.random.split(key, 4))
        state = type(specs)(
            params=params,
            opt_state=jax.vmap(opt.init)(params),
            step=jnp.zeros((), jnp.int32),
            rng=jax.random.PRNGKey(1),
            buffer=jax.tree_util.tree_map(jnp.copy, params),
            age=jnp.zeros((4,), jnp.int32))
        batch = jax.tree_util.tree_map(
            lambda s: jnp.zeros(s.shape, s.dtype), bspecs)
        with mesh:
            run = jit_train_step(step)
            ages = []
            for _ in range(4):
                state, metrics = run(state, batch)
                ages.append(int(jnp.max(state.age)))
        out["adpsgd_exec"] = {
            "loss_finite": bool(jnp.isfinite(metrics["loss"])),
            "step": int(state.step),
            "max_age_seen": max(ages)}

# elastic membership on the launch path (DESIGN 15): gated hypercube
# gossip + membership operands, driven by the SAME FaultPlan harness as
# the vmap trainer — crash, straggle, drop a round, quarantine-rejoin
import numpy as np
from repro.core import FaultPlan, Membership
from repro.core.faults import apply_plan
from repro.launch.train import membership_operands

mem = Membership(4)
plan = FaultPlan(FaultPlan.crash_rejoin(1, 2, 6).events
                 + FaultPlan.straggler(0, 3).events)
estep = make_adpsgd_train_step(api, opt, mesh, max_staleness=4,
                               elastic=True)
key = jax.random.PRNGKey(2)
params = jax.vmap(lambda k: api.init(k))(jax.random.split(key, 4))
especs = train_state_specs(api, opt, mesh, algo="adpsgd", elastic=True)
state = type(especs)(
    params=params,
    opt_state=jax.vmap(opt.init)(params),
    step=jnp.zeros((), jnp.int32),
    rng=jax.random.PRNGKey(3),
    buffer=jax.tree_util.tree_map(jnp.copy, params),
    age=jnp.zeros((4,), jnp.int32),
    **membership_operands(mem))
bspecs = api.train_batch_spec(8, 64)
batch = jax.tree_util.tree_map(lambda s: jnp.zeros(s.shape, s.dtype), bspecs)
leaf0 = lambda p: jax.tree_util.tree_leaves(p)[0]
dead_row, frozen, n_act, losses_fin = None, False, [], []
with mesh:
    erun = jit_train_step(estep)
    for i in range(8):
        drop = apply_plan(mem, plan, i)
        state = state._replace(**membership_operands(mem, drop_round=drop))
        state, metrics = erun(state, batch)
        n_act.append(int(metrics["n_active"]))
        losses_fin.append(bool(jnp.isfinite(metrics["loss"])))
        if i == 2:
            dead_row = np.asarray(leaf0(state.params)[1])
        if i == 5:
            frozen = bool(
                (np.asarray(leaf0(state.params)[1]) == dead_row).all())
try:
    cache = int(erun._cache_size())
except Exception:
    cache = 1
out["elastic_exec"] = {"losses_finite": all(losses_fin),
                       "n_active": n_act, "dead_row_frozen": frozen,
                       "cache_size": cache, "step": int(state.step)}

# decode lowering
params_specs = jax.eval_shape(api.init, jax.random.PRNGKey(0))
params_shd = shd.params_sharding(params_specs, mesh, stacked=False)
cache_specs = jax.eval_shape(lambda: api.init_cache(None, 8, 64))
cache_shd = shd.cache_sharding(cache_specs, mesh)
tok = jax.ShapeDtypeStruct((8, 1), jnp.int32)
tok_shd = shd.batch_sharding(tok, mesh, stacked=False)
with mesh:
    c = jax.jit(make_decode_step(api),
                in_shardings=shd.named_shardings(
                    (params_shd, cache_shd, tok_shd, P()), mesh),
                out_shardings=shd.named_shardings((None, cache_shd), mesh)).lower(
        params_specs, cache_specs, tok, jax.ShapeDtypeStruct((), jnp.int32)
    ).compile()
out["decode_ok"] = True
print(json.dumps(out))
"""


@pytest.fixture(scope="module")
def launch_results():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, env=env, cwd=os.path.dirname(
                           os.path.dirname(os.path.abspath(__file__))),
                       timeout=900)
    assert r.returncode == 0, r.stderr[-3000:]
    return json.loads(r.stdout.strip().splitlines()[-1])


def test_all_paths_lower(launch_results):
    assert launch_results["decode_ok"]
    assert "dpsgd_einsum" in launch_results
    assert "ssgd_einsum" in launch_results


def test_ppermute_backend_uses_collective_permute(launch_results):
    pp = launch_results["dpsgd_ppermute"]
    assert pp.get("collective-permute", 0) > 0
    # the optimized backend must move strictly fewer all-gathers than einsum
    eins = launch_results["dpsgd_einsum"]
    assert pp.get("all-gather", 0) < eins.get("all-gather", 0)


def test_adpsgd_lowers_with_collective_permute(launch_results):
    """The async path's only cross-learner traffic is the ONE pairwise
    buffer exchange — a collective-permute, never a learner all-gather."""
    ad = launch_results["adpsgd_ppermute"]
    assert ad.get("collective-permute", 0) > 0
    eins = launch_results["dpsgd_einsum"]
    assert ad.get("all-gather", 0) < eins.get("all-gather", 0)


def test_adpsgd_trains_under_pjit(launch_results):
    """4 executed ticks with a 3x straggler: finite loss, advancing step,
    staleness actually observed on the sharded age vector."""
    ex = launch_results["adpsgd_exec"]
    assert ex["loss_finite"]
    assert ex["step"] == 4
    assert 0 < ex["max_age_seen"] <= 4


def test_ssgd_has_gradient_allreduce(launch_results):
    assert launch_results["ssgd_einsum"].get("all-reduce", 0) > 0


def test_elastic_membership_on_launch_path(launch_results):
    """Crash/straggle/drop/rejoin via FaultPlan on the pjit path: losses
    stay finite, the live count tracks the plan, the crashed learner's
    rows are bitwise-frozen while dead, and every membership change is a
    same-shape operand swap (ONE compiled step for the whole run)."""
    ex = launch_results["elastic_exec"]
    assert ex["losses_finite"]
    assert ex["step"] == 8
    # crash at tick 2 (visible from tick 2's metrics on), rejoin at 6
    assert ex["n_active"] == [4, 4, 3, 3, 3, 3, 4, 4]
    assert ex["dead_row_frozen"]
    # at most 2 compiles: one cold, one when the first step's outputs come
    # back committed to their shardings — the crash (tick 2), drop-round
    # toggles and rejoin (tick 6) operand swaps must add ZERO retraces
    assert ex["cache_size"] <= 2
