"""Fault-injection harness contracts (DESIGN §15).

  * FaultPlan — deterministic under a seed, sorted, floor on live count;
  * apply_plan — the shared injection path: event semantics, rejoin
    surgery ordering, drop-round signalling;
  * Supervisor — scripted crash/rejoin/slow/drop scenarios drive a real
    trainer to finite losses; a transiently wedged learner is recovered
    through the retry ladder, a sticky (recovery-proof) hang is evicted
    after bounded retries with doubling backoff;
  * AdaScale — gain stays in [1, n_active], degenerates correctly at the
    consensus and pure-noise extremes, and composed with AutoLR the
    emitted multiplier keeps alpha_eff * lambda_max <= rho < 2 across a
    fleet resize;
  * crash-safe checkpoints — a kill mid-write leaves no visible partial
    file; restore falls back past truncated/bit-flipped checkpoints and
    refuses an explicitly-requested corrupt step.
"""
import glob
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (latest_step, restore_checkpoint,
                              save_checkpoint, verify_checkpoint)
from repro.core import (AlgoConfig, FaultEvent, FaultPlan, Membership,
                        MultiLearnerTrainer, Supervisor)
from repro.core.faults import apply_plan
from repro.core.membership import HUNG
from repro.data import ShardedLoader, TemplateImages
from repro.landscape import AutoLRController
from repro.landscape.probe import ProbeResult
from repro.models import fcnet
from repro.optim import AdaScale, AdaScaleAutoLR, sgd

N = 5
LOADER = ShardedLoader(TemplateImages(), n_learners=N, local_batch=32,
                       seed=0)
PARAMS = fcnet.init_params(jax.random.PRNGKey(0), in_dim=784, hidden=50)


def _trainer(algo="dpsgd", engine="flat", **kw):
    if algo == "adpsgd":
        kw.setdefault("max_staleness", 4)
    return MultiLearnerTrainer(
        fcnet.loss_fn, sgd(0.1, momentum=0.9),
        AlgoConfig(algo=algo, topology="random_pair", n_learners=N,
                   noise_std=0.0, **kw),
        engine=engine)


def _elastic_state(tr, seed=1):
    mem = Membership(N)
    st = tr.set_membership(tr.init(jax.random.PRNGKey(seed), PARAMS), mem)
    return st, mem


# ---------------------------------------------------------------------------
# FaultPlan
# ---------------------------------------------------------------------------

def test_plan_events_sorted_and_queryable():
    plan = FaultPlan((FaultEvent(9, "crash", 1), FaultEvent(2, "slow", 0, 3),
                      FaultEvent(9, "drop_round")))
    assert [e.step for e in plan.events] == [2, 9, 9]
    assert plan.last_step == 9
    assert {e.kind for e in plan.at(9)} == {"crash", "drop_round"}
    assert plan.at(5) == []


def test_plan_rejects_unknown_kind():
    with pytest.raises(AssertionError):
        FaultPlan((FaultEvent(0, "explode", 0),))


def test_random_plan_is_seed_deterministic():
    a = FaultPlan.random(7, steps=200, capacity=8)
    b = FaultPlan.random(7, steps=200, capacity=8)
    c = FaultPlan.random(8, steps=200, capacity=8)
    assert a.events == b.events
    assert a.events != c.events
    assert a.events   # 200 steps of default rates produce SOME faults


def test_random_plan_respects_min_active_floor():
    plan = FaultPlan.random(3, steps=500, capacity=4, p_crash=0.5,
                            p_rejoin=0.05, min_active=2)
    active = np.ones(4, bool)
    for ev in plan.events:
        if ev.kind == "crash":
            active[ev.learner] = False
        elif ev.kind == "rejoin":
            active[ev.learner] = True
        assert active.sum() >= 2, ev


def test_apply_plan_semantics_and_rejoin_ordering():
    mem = Membership(4)
    seen = []
    plan = FaultPlan((
        FaultEvent(0, "crash", 2), FaultEvent(0, "slow", 1, 3),
        FaultEvent(1, "rejoin", 2), FaultEvent(1, "drop_round"),
        FaultEvent(2, "hang", 0, True), FaultEvent(3, "recover", 0)))
    sticky = set()
    assert apply_plan(mem, plan, 0, sticky=sticky) is False
    assert not mem.active[2] and mem.slow_every[1] == 3

    # on_rejoin must observe the PRE-flip mask (admit clones live consensus)
    drop = apply_plan(mem, plan, 1, sticky=sticky,
                      on_rejoin=lambda s: seen.append(
                          (s, mem.active.copy())))
    assert drop is True
    assert seen[0][0] == 2 and not seen[0][1][2]   # still dead when called
    assert mem.active[2] and mem.incarnation[2] == 1

    apply_plan(mem, plan, 2, sticky=sticky)
    assert mem.slow_every[0] == HUNG and sticky == {0}
    apply_plan(mem, plan, 3, sticky=sticky)
    assert mem.slow_every[0] == 1 and sticky == set()


# ---------------------------------------------------------------------------
# Supervisor scenarios on the real trainer
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algo,engine", [("dpsgd", "flat"),
                                         ("dpsgd", "pytree"),
                                         ("adpsgd", "flat")])
def test_supervised_crash_rejoin_run(algo, engine):
    tr = _trainer(algo, engine)
    st, mem = _elastic_state(tr)
    plan = FaultPlan(FaultPlan.crash_rejoin(1, 3, 7).events
                     + (FaultEvent(5, "drop_round"),
                        FaultEvent(0, "slow", 0, 2)))
    sup = Supervisor(tr, mem, plan)
    st, losses = sup.run(st, LOADER.batch, steps=10)
    assert all(np.isfinite(losses))
    assert sup.report.crashes == [(3, 1)]
    assert sup.report.rejoins == [(7, 1)]
    assert sup.report.dropped_rounds == 1
    assert sup.report.evictions == []
    assert mem.n_active == N


@pytest.mark.parametrize("algo", ["dpsgd", "adpsgd"])
def test_supervisor_evicts_sticky_hang_after_backoff(algo):
    tr = _trainer(algo)
    st, mem = _elastic_state(tr)
    plan = FaultPlan((FaultEvent(0, "hang", 2, True),))   # recovery-proof
    sup = Supervisor(tr, mem, plan, staleness_bound=1, grace=1,
                     max_retries=2)
    st, losses = sup.run(st, LOADER.batch, steps=20)
    assert all(np.isfinite(losses))
    # retry ladder: thresholds 1, 2, 4 ticks -> two retries then eviction
    assert [s for s, i in sup.report.retries if i == 2]
    assert len([1 for s, i in sup.report.retries if i == 2]) == 2
    assert [i for _, i in sup.report.evictions] == [2]
    assert not mem.active[2] and mem.n_active == N - 1


def test_supervisor_recovers_transient_hang():
    tr = _trainer("dpsgd")
    st, mem = _elastic_state(tr)
    plan = FaultPlan((FaultEvent(0, "hang", 1),))         # transient wedge
    sup = Supervisor(tr, mem, plan, staleness_bound=1, grace=1,
                     max_retries=3)
    st, losses = sup.run(st, LOADER.batch, steps=12)
    assert all(np.isfinite(losses))
    assert [i for _, i in sup.report.retries][:1] == [1]  # retried...
    assert sup.report.evictions == []                     # ...not evicted
    assert mem.active[1] and mem.slow_every[1] == 1       # and healthy again


def test_supervised_chaos_run_stays_finite():
    tr = _trainer("dpsgd")
    st, mem = _elastic_state(tr)
    plan = FaultPlan.random(0, steps=15, capacity=N, min_active=2)
    sup = Supervisor(tr, mem, plan)
    st, losses = sup.run(st, LOADER.batch, steps=15)
    assert all(np.isfinite(losses))
    assert mem.n_active >= 2


# ---------------------------------------------------------------------------
# AdaScale gain + AutoLR clamp composition
# ---------------------------------------------------------------------------

def test_adascale_gain_bounds_and_extremes():
    n = 8.0
    # exact consensus: every learner's gradient identical -> gain == 1
    ada = AdaScale(theta=0.0)
    assert ada.update(grad_sq_mean=4.0, grad_norm_sq=4.0, n_active=n) == 1.0
    # pure noise: mean gradient ~ 0 -> gain -> n (clamped at n)
    ada = AdaScale(theta=0.0)
    g = ada.update(grad_sq_mean=4.0, grad_norm_sq=4.0 / n, n_active=n)
    assert g == pytest.approx(n, rel=0.2) and g <= n
    # mixed regime stays inside [1, n] for arbitrary inputs
    ada = AdaScale(theta=0.5)
    rng = np.random.default_rng(0)
    for _ in range(50):
        m2 = float(rng.uniform(0, 10))
        mb = float(rng.uniform(0, 10))
        nact = float(rng.integers(1, 9))
        g = ada.update(m2, mb, nact)
        assert 1.0 <= g <= 8.0
    # NaN metrics hold the last gain instead of poisoning it
    before = ada.gain
    assert ada.update(float("nan"), 1.0, 4.0) == before
    ada.reset_smoothing()
    assert ada.sigma_sq is None and ada.mu_sq is None


def test_adascale_single_survivor_gain_is_one():
    ada = AdaScale(theta=0.0)
    assert ada.update(5.0, 1.0, n_active=1.0) == 1.0


def _probe(sharpness):
    z = jnp.float32(0.0)
    return ProbeResult(sharpness=jnp.float32(sharpness), trace_h=z,
                       trace_hc=z, sigma_w_sq=z, grad_norm=jnp.float32(1.0),
                       gns=z, alpha_e_pred=z)


class _Metrics:
    def __init__(self, m2, gn, n):
        self.grad_sq_mean, self.grad_norm, self.n_active = m2, gn, n


def test_adascale_autolr_clamp_binds_across_resize():
    alpha0 = 0.5
    ctl = AutoLRController(alpha0=alpha0, rho=1.8, max_scale=8.0, ema=0.0)
    comp = AdaScaleAutoLR(ctl, AdaScale(theta=0.0))
    lam = 10.0
    comp.on_probe(_probe(lam))
    # a grown fleet in the noise-dominated regime asks for a big gain...
    n = 8.0
    scale = comp.on_metrics(_Metrics(4.0, np.sqrt(4.0 / n), n))
    # ...but the stability edge binds: alpha_eff * lambda <= rho < 2
    assert scale * alpha0 * lam <= 1.8 + 1e-9
    assert scale == pytest.approx(1.8 / (alpha0 * lam))
    # resize down to consensus-dominated: gain collapses to ~1, clamp slack
    comp.adascale.reset_smoothing()
    scale2 = comp.on_metrics(_Metrics(4.0, 2.0, 2.0))
    assert scale2 * alpha0 * lam <= 1.8 + 1e-9
    assert comp.adascale.gain == 1.0
    # max_gain cap is honored when the clamp is slack
    comp2 = AdaScaleAutoLR(AutoLRController(alpha0=0.01, ema=0.0,
                                            max_scale=100.0),
                           AdaScale(theta=0.0), max_gain=2.0)
    comp2.on_probe(_probe(1.0))
    s = comp2.on_metrics(_Metrics(4.0, np.sqrt(4.0 / 8), 8.0))
    assert s <= 2.0 * comp2.autolr.scale + 1e-9


# ---------------------------------------------------------------------------
# crash-safe checkpoints
# ---------------------------------------------------------------------------

TREE = {"w": jnp.arange(12.0).reshape(3, 4), "t": jnp.int32(7)}


def test_kill_mid_write_leaves_no_visible_checkpoint(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 1, TREE)

    # simulate the writer dying mid-write: np.savez raises after partial IO
    class Bomb:
        dtype = np.float32

        def __array__(self):
            raise KeyboardInterrupt("killed mid-serialize")

    with pytest.raises(KeyboardInterrupt):
        save_checkpoint(d, 2, {"w": Bomb()})
    assert latest_step(d) == 1                       # step 2 never visible
    assert not glob.glob(os.path.join(d, "*.tmp"))   # temp cleaned up
    tree, step = restore_checkpoint(d, TREE)
    assert step == 1
    np.testing.assert_array_equal(np.asarray(tree["w"]),
                                  np.asarray(TREE["w"]))


def test_restore_falls_back_past_corrupt_latest(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 10, TREE)
    path20 = save_checkpoint(d, 20, TREE)
    # truncate the newest file: a torn write that somehow became visible
    data = open(path20, "rb").read()
    open(path20, "wb").write(data[:len(data) // 2])
    assert not verify_checkpoint(d, 20)
    assert verify_checkpoint(d, 10)
    tree, step = restore_checkpoint(d, TREE)         # falls back, loudly
    assert step == 10
    with pytest.raises(ValueError, match="corrupt"):
        restore_checkpoint(d, TREE, step=20)         # explicit is strict


def test_restore_detects_bit_flip(tmp_path):
    d = str(tmp_path)
    path = save_checkpoint(d, 5, TREE)
    blob = bytearray(open(path, "rb").read())
    # flip a byte INSIDE the 'w' payload (the f32 value 5.0), not in inert
    # zip padding — targeted disk damage the digest must catch
    off = blob.find(np.float32(5.0).tobytes())
    assert off > 0
    blob[off] ^= 0xFF
    open(path, "wb").write(bytes(blob))
    assert not verify_checkpoint(d, 5)
    with pytest.raises(FileNotFoundError, match="no uncorrupted"):
        restore_checkpoint(d, TREE)


def test_checkpoint_roundtrip_under_supervisor(tmp_path):
    """A mid-run checkpoint of an elastic state restores bit-exactly."""
    tr = _trainer("dpsgd")
    st, mem = _elastic_state(tr)
    sup = Supervisor(tr, mem, FaultPlan.crash_rejoin(1, 2))
    st, _ = sup.run(st, LOADER.batch, steps=4)
    ckpt = {"params": tr.params_tree(st), "step": st.step}
    save_checkpoint(str(tmp_path), int(st.step), ckpt)
    back, step = restore_checkpoint(str(tmp_path), ckpt)
    for a, b in zip(jax.tree_util.tree_leaves(back["params"]),
                    jax.tree_util.tree_leaves(ckpt["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
