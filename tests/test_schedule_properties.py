"""Property-based conformance suite for the GossipSchedule engine
(DESIGN §12).

Every schedule claim the rest of the system leans on is pinned here:

  * every realized per-step mixing matrix of every schedule is doubly
    stochastic, and symmetric exactly where the schedule claims it;
  * every deterministic partner row is a permutation of range(n) — the
    contract that lets the launch path turn the same tables into
    collective-permutes;
  * consensus distance contracts at >= the spectral-gap rate over a window
    (the submultiplicative eta-product bound), measured BOTH on the dense
    matrices and through the fused kernel's mixing-only path;
  * the one-peer exponential schedule averages to the static exponential
    matrix over its period;
  * the multi-round compilations (full-as-rounds, hierarchical) reproduce
    their dense one-shot matrices exactly;
  * spectral_gap_profile's measured rate never beats its own bound.

With hypothesis installed (the [test] extra) the sweeps fuzz their input
space; without it they degrade to a pinned deterministic grid so the
conformance guarantees stay tier-1 either way.
"""
import itertools
import math

import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    HAVE_HYPOTHESIS = False

from repro.core import schedule as gsched
from repro.core import topology as topo

ALL = gsched.SCHEDULED_TOPOLOGIES
DET = gsched.DETERMINISTIC_TOPOLOGIES

# pinned fallback grid (hypothesis absent): spans odd/even/prime/power-of-2
NS = (2, 3, 5, 8, 12, 16)
SEEDS = (0, 17)


def sweep(max_examples=60, **dims):
    """@given(...) under hypothesis, deterministic grid parametrize without.

    ``dims`` maps argument name -> (hypothesis strategy, fallback values).
    """
    names = list(dims)
    if HAVE_HYPOTHESIS:
        def deco(fn):
            return settings(max_examples=max_examples, deadline=None)(
                given(**{k: v[0] for k, v in dims.items()})(fn))
        return deco
    grid = list(itertools.product(*(dims[k][1] for k in names)))
    if len(names) == 1:
        grid = [g[0] for g in grid]
    return pytest.mark.parametrize(",".join(names), grid)


def _topos(values=ALL):
    return (st.sampled_from(values) if HAVE_HYPOTHESIS else None, values)


def _ints(lo, hi, fallback):
    return (st.integers(lo, hi) if HAVE_HYPOTHESIS else None, fallback)


def _realize(name, n, seed, step, rounds=2):
    s = gsched.make_schedule(name, n, rounds=rounds)
    m = np.asarray(s.step_matrix(jax.random.PRNGKey(seed), step), np.float64)
    return s, m


# ---------------------------------------------------------------------------
# double stochasticity + symmetry-where-claimed
# ---------------------------------------------------------------------------

@sweep(name=_topos(), n=_ints(2, 24, NS), seed=_ints(0, 1000, SEEDS),
       step=_ints(0, 50, (0, 3)))
def test_every_realized_step_matrix_doubly_stochastic(name, n, seed, step):
    s, m = _realize(name, n, seed, step)
    assert topo.is_doubly_stochastic(m), (name, n, step)
    if s.symmetric:
        np.testing.assert_allclose(m, m.T, atol=1e-6, err_msg=f"{name} n={n}")


@sweep(max_examples=20, n=_ints(2, 24, NS), seed=_ints(0, 500, SEEDS))
def test_asymmetric_schedules_still_preserve_the_mean(n, seed):
    """exp / one-peer exp drop symmetry but keep double stochasticity, so
    the average weight still moves by the average gradient (paper Eq. 3)."""
    x = np.asarray(jax.random.normal(jax.random.PRNGKey(seed), (n, 7)),
                   np.float64)
    for name in ("exp", "one_peer_exp"):
        _, m = _realize(name, n, seed, step=seed % 5)
        np.testing.assert_allclose((m @ x).mean(0), x.mean(0), atol=1e-10)


# ---------------------------------------------------------------------------
# table contract: static K, permutation rows, zero-padded slots
# ---------------------------------------------------------------------------

@sweep(max_examples=40, name=_topos(DET), n=_ints(2, 24, NS))
def test_deterministic_partner_rows_are_permutations(name, n):
    s = gsched.make_schedule(name, n)
    assert s.perm_rounds
    assert s.partners.shape == (s.period, s.K, n)
    assert s.coefs.shape == (s.period, n, s.K + 1)
    for r in range(s.period):
        for k in range(s.K):
            row = np.sort(s.partners[r, k])
            np.testing.assert_array_equal(row, np.arange(n), err_msg=name)
    # coefficients are non-negative and each row sums to 1 (row stochastic
    # by construction; column stochasticity is the matrix test above)
    assert (s.coefs >= 0).all()
    np.testing.assert_allclose(s.coefs.sum(-1), 1.0, atol=1e-6)


@sweep(max_examples=25, n=_ints(2, 24, NS), seed=_ints(0, 1000, SEEDS))
def test_random_matching_tables_match_pair_partners(n, seed):
    """The randomized schedule's round-0 tables are the legacy
    pair_partners draw, bit for bit — the PR 3 bitwise contracts
    (AD-PSGD == sync DPSGD at staleness 0) ride on this."""
    s = gsched.make_schedule("random_pair", n)
    key = jax.random.PRNGKey(seed)
    (partners, coefs), = s.step_rounds(key, 0)
    partner = np.asarray(topo.pair_partners(key, n))
    np.testing.assert_array_equal(np.asarray(partners[0]), partner)
    solo = partner == np.arange(n)
    np.testing.assert_array_equal(np.asarray(coefs[:, 0]),
                                  np.where(solo, 1.0, 0.5).astype(np.float32))


# ---------------------------------------------------------------------------
# consensus contraction >= the spectral-gap rate over a window
# ---------------------------------------------------------------------------

def _dis(x):
    return float(np.linalg.norm(x - x.mean(0, keepdims=True)))


@sweep(max_examples=40, name=_topos(), n=_ints(3, 16, (3, 8, 12)),
       seed=_ints(0, 500, SEEDS))
def test_consensus_contracts_at_least_at_spectral_gap_rate(name, n, seed):
    """Over a window, disagreement shrinks by AT LEAST the product of the
    per-step 1-lambda_2 contraction factors (eta_t = ||M_t - J||_2)."""
    s = gsched.make_schedule(name, n, rounds=2)
    key = jax.random.PRNGKey(seed)
    window = max(6, 2 * s.period)
    x = np.asarray(jax.random.normal(jax.random.PRNGKey(seed + 1), (n, 9)),
                   np.float64)
    d0 = _dis(x)
    bound = 1.0
    J = np.full((n, n), 1.0 / n)
    for t in range(window):
        kt = jax.random.fold_in(key, t)
        m = np.asarray(s.step_matrix(kt, t), np.float64)
        x = m @ x
        bound *= np.linalg.norm(m - J, 2)
    assert _dis(x) <= bound * d0 * (1 + 1e-6) + 1e-9, (name, n)


@pytest.mark.parametrize("name", ALL)
def test_consensus_contraction_holds_through_the_kernel_path(name):
    """Same property measured through ops.flat_gossip_mix — the mixing the
    fused engine actually executes — instead of dense matrices."""
    from repro.kernels.ops import flat_gossip_mix
    n, T = 8, 16
    s = gsched.make_schedule(name, n, rounds=2)
    key = jax.random.PRNGKey(3)
    w = jax.random.normal(jax.random.PRNGKey(4), (n, T, 128))
    d0 = _dis(np.asarray(w, np.float64).reshape(n, -1))
    window = max(4, 2 * s.period)
    bound = 1.0
    J = np.full((n, n), 1.0 / n)
    for t in range(window):
        kt = jax.random.fold_in(key, t)
        for partners, coefs in s.step_rounds(kt, t):
            w = flat_gossip_mix(w, partners, coefs, backend="ref")
        m = np.asarray(s.step_matrix(kt, t), np.float64)
        bound *= np.linalg.norm(m - J, 2)
    d = _dis(np.asarray(w, np.float64).reshape(n, -1))
    assert d <= bound * d0 * (1 + 1e-4) + 1e-6, (name, d, bound * d0)


@sweep(max_examples=30, name=_topos(), n=_ints(2, 16, (2, 8)))
def test_profile_measured_rate_never_beats_its_bound(name, n):
    p = gsched.spectral_gap_profile(gsched.make_schedule(name, n, rounds=2))
    assert p["measured_rate"] <= p["bound_rate"] + 1e-9, (name, n, p)
    assert 0.0 <= p["measured_rate"] <= 1.0 + 1e-9


# ---------------------------------------------------------------------------
# schedule identities
# ---------------------------------------------------------------------------

@sweep(max_examples=23, n=_ints(2, 24, NS))
def test_one_peer_exp_averages_to_static_exp_over_its_period(n):
    op = gsched.make_schedule("one_peer_exp", n)
    ex = gsched.make_schedule("exp", n)
    assert op.period == max(1, int(math.ceil(math.log2(n))))
    np.testing.assert_allclose(op.mean_matrix(),
                               np.asarray(ex.step_mats[0], np.float64),
                               atol=1e-7)
    np.testing.assert_allclose(np.asarray(ex.step_mats[0], np.float64),
                               np.asarray(topo.exponential_matrix(n),
                                          np.float64), atol=1e-7)


@sweep(max_examples=23, n=_ints(2, 24, NS))
def test_full_as_rounds_product_is_exact_full_average(n):
    s = gsched.make_schedule("full", n)
    if n & (n - 1) == 0 and n > 1:
        assert s.K == 1 and s.period == int(math.log2(n))   # hypercube
    np.testing.assert_allclose(np.asarray(s.step_matrix(None, 0), np.float64),
                               np.asarray(topo.full_matrix(n), np.float64),
                               atol=1e-6)


@sweep(max_examples=21, n=_ints(4, 24, (4, 8, 9, 12, 16)))
def test_hierarchical_rounds_product_matches_dense_matrix(n):
    s = gsched.make_schedule("hierarchical", n)
    S, g = gsched._hier_dims(n)
    if 1 < g < n:
        expect = topo.hierarchical_matrix(S, g)
        assert s.period == 2        # intra-full then inter-ring
    elif g == n or S == 1:
        expect = topo.full_matrix(n)
    else:
        expect = topo.ring_matrix(n)
    np.testing.assert_allclose(np.asarray(s.step_matrix(None, 0), np.float64),
                               np.asarray(expect, np.float64), atol=1e-6)


@sweep(max_examples=30,
       name=_topos(("ring", "torus", "full", "hierarchical", "exp")),
       n=_ints(2, 16, (2, 5, 8)))
def test_static_schedules_match_make_mixing_fn(name, n):
    """The compiled schedule realizes the same matrix as the legacy dense
    constructor for every static topology both systems express."""
    if name == "hierarchical":
        _, g = gsched._hier_dims(n)
        if g in (1, n):
            return      # degenerate factorization delegates (covered above)
    s = gsched.make_schedule(name, n)
    m = topo.make_mixing_fn(name, n)(jax.random.PRNGKey(0))
    np.testing.assert_allclose(np.asarray(s.step_matrix(None, 0), np.float64),
                               np.asarray(m, np.float64), atol=1e-6)


def test_solo_and_unknown():
    assert gsched.make_schedule("solo", 8) is None
    assert gsched.make_schedule("ring", 1) is None
    with pytest.raises(ValueError):
        gsched.make_schedule("nope", 8)


def test_time_varying_classification():
    assert not gsched.make_schedule("ring", 8).time_varying
    assert not gsched.make_schedule("full", 8).time_varying     # whole cycle
    assert not gsched.make_schedule("hierarchical", 8).time_varying
    assert gsched.make_schedule("one_peer_exp", 8).time_varying
    assert gsched.make_schedule("random_pair", 8).time_varying
    assert gsched.make_schedule("random_matching", 8, rounds=3).time_varying
