"""Elastic membership contracts (DESIGN §15).

Pins the tentpole guarantees of PR 8:

  * only-active matching — ``masked_pair_partners`` is an involution that
    never pairs across the liveness boundary, and with everyone live it
    reproduces the legacy ``pair_partners`` matching BITWISE;
  * reschedule conformance — for every deterministic topology and several
    active-set sizes (including non-power-of-two shrinks of ``full``),
    every realized matrix is doubly stochastic at capacity, identity on
    the dead slots, and restricts EXACTLY to ``make_schedule(topology,
    n_active)`` on the live ones; the active-set spectral profile still
    contracts;
  * elastic == legacy — an all-active elastic state trains bitwise
    identically to the fixed-fleet path (DPSGD and AD-PSGD, flat and
    pytree engines);
  * quarantine — a crashed learner's rows are bitwise-frozen, and even
    NaN-poisoning them leaves every live learner's trajectory bitwise
    unchanged and finite;
  * admit — a consensus join clones the live mean into the slot and
    training continues finite;
  * the serving bridge excludes dead rows from the consensus snapshot.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AlgoConfig, Membership, MultiLearnerTrainer, admit,
                        reschedule)
from repro.core import schedule as gsched
from repro.core import topology as topo
from repro.data import ShardedLoader, TemplateImages
from repro.models import fcnet
from repro.optim import sgd
from repro.serve.bridge import ConsensusBridge

N = 5
LOADER = ShardedLoader(TemplateImages(), n_learners=N, local_batch=32,
                       seed=0)
PARAMS = fcnet.init_params(jax.random.PRNGKey(0), in_dim=784, hidden=50)


def _trainer(algo, engine, topology="random_pair", n=N, **kw):
    if algo == "adpsgd":
        kw.setdefault("max_staleness", 4)
    return MultiLearnerTrainer(
        fcnet.loss_fn, sgd(0.1, momentum=0.9),
        AlgoConfig(algo=algo, topology=topology, n_learners=n,
                   noise_std=0.0, **kw),
        engine=engine)


def _params_np(tr, st):
    return [np.asarray(x) for x in
            jax.tree_util.tree_leaves(tr.params_tree(st))]


def _run(tr, st, steps, loader=LOADER, start=0):
    for i in range(start, start + steps):
        st, m = tr.train_step(st, loader.batch(i))
    return st, m


def _copy_state(st):
    """Deep-copy every array leaf: train_step donates its input state, so
    two states that share buffers cannot both be stepped."""
    return jax.tree_util.tree_map(lambda x: jnp.array(x, copy=True), st)


# ---------------------------------------------------------------------------
# masked matching
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [2, 5, 8, 13])
def test_masked_matching_all_active_matches_legacy_bitwise(n):
    for seed in range(6):
        key = jax.random.PRNGKey(seed)
        legacy = topo.pair_partners(key, n)
        masked = topo.masked_pair_partners(key, jnp.ones((n,), bool))
        np.testing.assert_array_equal(np.asarray(masked), np.asarray(legacy))


@pytest.mark.parametrize("n,live", [(5, [0, 2, 3]), (8, [1]), (8, [0, 7]),
                                    (6, [0, 1, 2, 3, 4]), (4, [])])
def test_masked_matching_only_pairs_active(n, live):
    active = np.zeros(n, bool)
    active[live] = True
    for seed in range(6):
        p = np.asarray(topo.masked_pair_partners(
            jax.random.PRNGKey(seed), jnp.asarray(active)))
        # involution, inactive solo, liveness boundary never crossed
        np.testing.assert_array_equal(p[p], np.arange(n))
        assert (p[~active] == np.flatnonzero(~active)).all()
        matched = p != np.arange(n)
        assert active[matched].all() and active[p[matched]].all()
        # even active count: everyone live is matched; odd: exactly one solo
        n_live_solo = int((~matched & active).sum())
        assert n_live_solo == (len(live) % 2 if live else 0)


def test_masked_matching_drop_round_forces_identity():
    active = jnp.ones((6,), bool)
    p = topo.masked_pair_partners(jax.random.PRNGKey(3), active,
                                  drop=jnp.asarray(True))
    np.testing.assert_array_equal(np.asarray(p), np.arange(6))


# ---------------------------------------------------------------------------
# reschedule conformance (satellite: every topology x several active sets)
# ---------------------------------------------------------------------------

CAP = 8
ACTIVE_SETS = (
    list(range(8)),            # full fleet
    [0, 2, 3, 4, 6],           # non-power-of-two shrink (8 -> 5)
    [1, 2, 5, 7],              # 4 live
    [0, 4],                    # pair
    [3],                       # lone survivor -> identity
)


@pytest.mark.parametrize("topology", gsched.DETERMINISTIC_TOPOLOGIES)
@pytest.mark.parametrize("live", ACTIVE_SETS,
                         ids=[f"m{len(a)}" for a in ACTIVE_SETS])
def test_reschedule_conformant_embedding(topology, live):
    active = np.zeros(CAP, bool)
    active[live] = True
    m = len(live)
    sched = reschedule(topology, active)
    inner = gsched.make_schedule(topology, m) if m > 1 else None
    steps = max(sched.period, 4)
    for t in range(steps):
        key = jax.random.PRNGKey(t)
        M = np.asarray(sched.step_matrix(key, t), np.float64)
        # doubly stochastic at capacity, nonnegative
        assert (M >= -1e-6).all()
        np.testing.assert_allclose(M.sum(0), 1.0, atol=1e-5)
        np.testing.assert_allclose(M.sum(1), 1.0, atol=1e-5)
        # dead slots: exact identity rows AND columns (no coupling)
        dead = ~active
        np.testing.assert_array_equal(M[dead][:, dead],
                                      np.eye(CAP - m))
        assert np.all(M[dead][:, active] == 0.0)
        assert np.all(M[active][:, dead] == 0.0)
        # live submatrix == the conformant n_active schedule, exactly
        if inner is not None:
            want = np.asarray(inner.step_matrix(key, t), np.float64)
            np.testing.assert_allclose(M[np.ix_(live, live)], want,
                                       atol=1e-6)
        else:
            np.testing.assert_array_equal(M[np.ix_(live, live)],
                                          np.eye(m))


@pytest.mark.parametrize("topology", ("full", "ring", "one_peer_exp"))
def test_reschedule_active_set_still_contracts(topology):
    active = np.zeros(CAP, bool)
    active[[0, 2, 3, 4, 6]] = True            # non-pow2 shrink of full
    prof = gsched.spectral_gap_profile(reschedule(topology, active),
                                       window=8)
    assert prof["measured_rate"] <= prof["bound_rate"] + 1e-9
    assert prof["measured_gap"] > 0.0         # live learners still mix


def test_reschedule_randomized_draws_from_mask():
    active = np.array([True, False, True, True, False])
    sched = reschedule("random_pair", active)
    assert sched.randomized and sched.n == 5
    np.testing.assert_array_equal(np.asarray(sched.active), active)


# ---------------------------------------------------------------------------
# elastic trainer == legacy trainer when everyone is live
# ---------------------------------------------------------------------------

PARITY_CASES = [
    ("dpsgd", "flat", "random_pair"),
    ("dpsgd", "flat", "ring"),
    ("dpsgd", "flat", "one_peer_exp"),
    ("dpsgd", "pytree", "random_pair"),
    ("adpsgd", "flat", "random_pair"),
    ("adpsgd", "pytree", "random_pair"),
]


@pytest.mark.parametrize("algo,engine,topology", PARITY_CASES)
def test_all_active_elastic_is_bitwise_legacy(algo, engine, topology):
    tr = _trainer(algo, engine, topology)
    st_legacy = tr.init(jax.random.PRNGKey(1), PARAMS)
    st_el = tr.set_membership(tr.init(jax.random.PRNGKey(1), PARAMS),
                              Membership(N))
    st_legacy, m_l = _run(tr, st_legacy, 4)
    st_el, m_e = _run(tr, st_el, 4)
    for a, b in zip(_params_np(tr, st_legacy), _params_np(tr, st_el)):
        np.testing.assert_array_equal(a, b)
    # the masked metric reduction (sum/n_active vs mean) may differ by ulps
    np.testing.assert_allclose(float(m_e.loss), float(m_l.loss), rtol=1e-6)
    assert int(m_e.n_active) == N


@pytest.mark.parametrize("algo,engine", [("dpsgd", "flat"),
                                         ("dpsgd", "pytree"),
                                         ("adpsgd", "flat")])
def test_crashed_row_frozen_and_garbage_invariant(algo, engine):
    tr = _trainer(algo, engine)
    mem = Membership(N)
    st = tr.set_membership(tr.init(jax.random.PRNGKey(2), PARAMS), mem)
    st, _ = _run(tr, st, 2)
    mem.crash(3)
    st = tr.set_membership(st, mem)
    dead_rows = [x[3] for x in _params_np(tr, st)]

    # a second fleet, identical except learner 3's quarantined rows are
    # poisoned with NaN: the live learners must not see the difference
    st_poison = _copy_state(st)
    view = tr.state_view(st_poison)
    poisoned = jax.tree_util.tree_map(
        lambda x: x.at[3].set(jnp.nan) if jnp.issubdtype(
            x.dtype, jnp.floating) and x.ndim >= 1 and x.shape[0] == N
        else x, view.params)
    st_poison = tr.state_from_view(view._replace(params=poisoned))
    if st.buffer is not None:
        bview = tr.state_view(st_poison)
        st_poison = tr.state_from_view(bview._replace(
            buffer=jax.tree_util.tree_map(
                lambda x: x.at[3].set(jnp.nan), bview.buffer)))

    st, m = _run(tr, st, 3, start=2)
    st_poison, m_p = _run(tr, st_poison, 3, start=2)

    for leaf, dead in zip(_params_np(tr, st), dead_rows):
        np.testing.assert_array_equal(leaf[3], dead)   # bitwise-frozen
    live = [0, 1, 2, 4]
    for a, b in zip(_params_np(tr, st), _params_np(tr, st_poison)):
        np.testing.assert_array_equal(a[live], b[live])
        assert np.isfinite(a[live]).all()
    assert float(m.loss) == float(m_p.loss) and np.isfinite(float(m.loss))
    assert int(m.n_active) == N - 1


@pytest.mark.parametrize("engine", ["flat", "pytree"])
def test_admit_clones_live_consensus(engine):
    tr = _trainer("dpsgd", engine)
    mem = Membership(N)
    st = tr.set_membership(tr.init(jax.random.PRNGKey(4), PARAMS), mem)
    st, _ = _run(tr, st, 2)
    mem.crash(1)
    st = tr.set_membership(st, mem)
    st, _ = _run(tr, st, 2, start=2)

    st2 = admit(tr, st, 1, mode="consensus")
    view = tr.state_view(st2)
    act = np.array([True, False, True, True, True])
    for leaf in jax.tree_util.tree_leaves(view.params):
        x = np.asarray(leaf)
        want = x[act].astype(np.float32).mean(0).astype(x.dtype)
        np.testing.assert_allclose(x[1], want, rtol=1e-5, atol=1e-7)
    mem.rejoin(1)
    assert mem.incarnation[1] == 1
    st2 = tr.set_membership(st2, mem)
    st2, m = _run(tr, st2, 2, start=4)
    assert np.isfinite(float(m.loss)) and int(m.n_active) == N


def test_bridge_snapshot_excludes_dead_rows():
    tr = _trainer("dpsgd", "flat")
    mem = Membership(N)
    st = tr.set_membership(tr.init(jax.random.PRNGKey(5), PARAMS), mem)
    st, _ = _run(tr, st, 2)
    mem.crash(2)
    st = tr.set_membership(st, mem)
    # poison the quarantined row: a folded-in dead row would blow up the mean
    view = tr.state_view(st)
    st = tr.state_from_view(view._replace(params=jax.tree_util.tree_map(
        lambda x: x.at[2].set(1e30), view.params)))

    bridge = ConsensusBridge(tr)
    snap = bridge.snapshot(st)
    assert snap.n_active == N - 1
    live = np.array([0, 1, 3, 4])
    stacked = tr.params_tree(st)
    for got, leaf in zip(jax.tree_util.tree_leaves(snap.params),
                         jax.tree_util.tree_leaves(stacked)):
        want = np.asarray(leaf)[live].astype(np.float32).mean(0)
        np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5,
                                   atol=1e-7)
        assert np.isfinite(np.asarray(got)).all()
    assert np.isfinite(bridge.staleness(st, snap)["consensus_dist_now"])


def test_set_membership_rejects_non_decentralized():
    tr = _trainer("ssgd", "pytree")
    st = tr.init(jax.random.PRNGKey(6), PARAMS)
    with pytest.raises(ValueError, match="decentralized"):
        tr.set_membership(st, Membership(N))
