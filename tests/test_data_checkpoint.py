import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.data import (GaussianMixtureImages, ShardedLoader,
                        SyntheticTokenStream, ZipfianTokenStream)


def test_loader_determinism_and_distinct_learners():
    ds = GaussianMixtureImages()
    ld = ShardedLoader(ds, n_learners=4, local_batch=8, seed=7)
    b1, b2 = ld.batch(3), ld.batch(3)
    np.testing.assert_array_equal(np.asarray(b1["image"]),
                                  np.asarray(b2["image"]))
    # different learners see different data at the same step
    assert not np.allclose(np.asarray(b1["image"][0]),
                           np.asarray(b1["image"][1]))
    # different steps differ
    b3 = ld.batch(4)
    assert not np.allclose(np.asarray(b1["image"]), np.asarray(b3["image"]))


def test_gaussian_mixture_is_learnable_shape():
    ds = GaussianMixtureImages(n_classes=10)
    b = ds.sample(jax.random.PRNGKey(0), 32)
    assert b["image"].shape == (32, 28, 28, 1)
    assert int(b["label"].max()) < 10


def test_token_stream_ranges():
    ds = SyntheticTokenStream(vocab=512)
    b = ds.sample(jax.random.PRNGKey(1), 4, 16)
    assert b["tokens"].shape == (4, 16)
    assert int(b["tokens"].max()) < 512
    # labels are next tokens
    full_ok = np.asarray(b["tokens"][:, 1:]) == np.asarray(b["labels"][:, :-1])
    assert full_ok.all()


def test_zipf_is_skewed():
    ds = ZipfianTokenStream(vocab=1000, alpha=1.5)
    b = ds.sample(jax.random.PRNGKey(2), 8, 128)
    toks = np.asarray(b["tokens"]).ravel()
    # head tokens dominate
    assert (toks < 10).mean() > 0.3


def test_checkpoint_roundtrip_with_opt_state():
    tree = {"params": {"w": jnp.arange(6.0).reshape(2, 3)},
            "opt": {"mu": jnp.ones((2, 3)), "t": jnp.int32(5)}}
    with tempfile.TemporaryDirectory() as d:
        assert latest_step(d) is None
        save_checkpoint(d, 10, tree)
        save_checkpoint(d, 20, tree)
        assert latest_step(d) == 20
        back, step = restore_checkpoint(d, tree)
        assert step == 20
        np.testing.assert_allclose(np.asarray(back["params"]["w"]),
                                   np.asarray(tree["params"]["w"]))
        assert back["opt"]["t"].dtype == jnp.int32
