import jax
import jax.numpy as jnp
import numpy as np

from repro.models.mamba import (_ssm_scan_chunked, init_mamba_cache,
                                init_mamba_params, mamba_decode,
                                mamba_forward)


def test_chunked_scan_matches_sequential():
    B, S, di, N = 2, 32, 8, 4
    key = jax.random.PRNGKey(0)
    a = jax.nn.sigmoid(jax.random.normal(key, (B, S, di, N)))
    b = jax.random.normal(jax.random.PRNGKey(1), (B, S, di, N))
    h0 = jnp.zeros((B, di, N))
    hs, hl = _ssm_scan_chunked(a, b, h0, chunk=8)
    # sequential reference
    h = h0
    ref = []
    for t in range(S):
        h = a[:, t] * h + b[:, t]
        ref.append(h)
    ref = jnp.stack(ref, 1)
    np.testing.assert_allclose(np.asarray(hs), np.asarray(ref), atol=1e-4)
    np.testing.assert_allclose(np.asarray(hl), np.asarray(ref[:, -1]), atol=1e-4)


def test_mamba_forward_shapes():
    key = jax.random.PRNGKey(2)
    p = init_mamba_params(key, 32)
    x = jax.random.normal(key, (2, 16, 32))
    y = mamba_forward(p, x, scan_chunk=8)
    assert y.shape == x.shape and bool(jnp.isfinite(y).all())


def test_mamba_decode_matches_forward():
    """Step-by-step decode reproduces the parallel forward (state-space
    consistency — the core SSM invariant)."""
    key = jax.random.PRNGKey(3)
    d = 16
    p = init_mamba_params(key, d)
    x = jax.random.normal(key, (1, 12, d))
    full = mamba_forward(p, x, scan_chunk=4)
    cache = init_mamba_cache(1, d)
    outs = []
    for t in range(12):
        o, cache = mamba_decode(p, cache, x[:, t:t + 1])
        outs.append(o)
    dec = jnp.concatenate(outs, 1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), atol=1e-3)
