"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="install the [test] extra for "
                    "property-based tests")
from hypothesis import given, settings, strategies as st

from repro.core import dpsgd, topology as topo
from repro.core.util import learner_mean, learner_var, tree_norm_sq, tree_sub
from repro.models.layers import apply_rope, cross_entropy, rms_norm, softcap


@given(st.integers(2, 16), st.integers(0, 10_000))
@settings(max_examples=20, deadline=None)
def test_any_mixing_matrix_preserves_mean(n, seed):
    key = jax.random.PRNGKey(seed)
    t = {"w": jax.random.normal(key, (n, 5, 3))}
    for name in ("full", "ring", "random_pair"):
        m = topo.make_mixing_fn(name, n)(key)
        out = dpsgd.mix_einsum(t, m)
        d = tree_norm_sq(tree_sub(learner_mean(t), learner_mean(out)))
        assert float(d) < 1e-7


@given(st.integers(2, 12), st.integers(0, 10_000))
@settings(max_examples=20, deadline=None)
def test_variance_never_increases_under_gossip(n, seed):
    key = jax.random.PRNGKey(seed)
    t = {"w": jax.random.normal(key, (n, 17))}
    for name in ("full", "ring", "random_pair"):
        m = topo.make_mixing_fn(name, n)(key)
        out = dpsgd.mix_einsum(t, m)
        assert float(learner_var(out)) <= float(learner_var(t)) + 1e-9


@given(st.integers(1, 64), st.integers(0, 1000))
@settings(max_examples=20, deadline=None)
def test_rope_preserves_pairwise_norm(pos, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (1, 1, 2, 32))
    y = apply_rope(x, jnp.array([pos]), theta=1e4)
    np.testing.assert_allclose(float(jnp.linalg.norm(x)),
                               float(jnp.linalg.norm(y)), rtol=1e-5)


@given(st.integers(2, 100))
@settings(max_examples=20, deadline=None)
def test_cross_entropy_uniform_is_log_v(v):
    logits = jnp.zeros((3, 4, v))
    labels = jnp.zeros((3, 4), jnp.int32)
    ce = cross_entropy(logits, labels)
    np.testing.assert_allclose(float(ce), float(jnp.log(v)), rtol=1e-5)


@given(st.floats(1.0, 100.0), st.floats(-500.0, 500.0))
@settings(max_examples=50, deadline=None)
def test_softcap_bounds(cap, x):
    y = float(softcap(jnp.float32(x), cap))
    assert abs(y) <= cap * 1.0001
    if abs(x) > 1e-3:  # sign preserved away from 0 (f32 rounding at 0)
        assert (y >= 0) == (x >= 0)


@given(st.integers(1, 8), st.integers(2, 64), st.integers(0, 100))
@settings(max_examples=20, deadline=None)
def test_rms_norm_scale_invariance(b, d, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (b, d)) + 0.1
    s = jnp.zeros((d,))
    y1 = rms_norm(x, s)
    y2 = rms_norm(3.0 * x, s)
    # eps=1e-6 breaks exact invariance for small-norm draws -> loose atol
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=5e-3)


@given(st.integers(2, 10), st.integers(0, 1000), st.floats(0.01, 0.2))
@settings(max_examples=15, deadline=None)
def test_ssgd_replicas_stay_identical(n, seed, lr):
    """SSGD invariant: all learner copies remain bitwise-identical forever."""
    from repro.core import AlgoConfig, MultiLearnerTrainer
    from repro.optim import sgd

    def loss_fn(p, b):
        return jnp.mean((b["x"] @ p["w"] - b["y"]) ** 2)

    key = jax.random.PRNGKey(seed)
    tr = MultiLearnerTrainer(loss_fn, sgd(lr, momentum=0.9),
                             AlgoConfig(algo="ssgd", n_learners=n))
    st_ = tr.init(key, {"w": jax.random.normal(key, (4, 1)) * 0.1})
    batch = {"x": jax.random.normal(key, (n, 8, 4)),
             "y": jnp.ones((n, 8, 1))}
    for _ in range(3):
        st_, _ = tr.train_step(st_, batch)
    assert float(learner_var(st_.params)) < 1e-12


@given(st.integers(4, 16), st.integers(0, 500))
@settings(max_examples=10, deadline=None)
def test_repeated_gossip_converges_to_consensus(n, seed):
    """Gossip mixing is a consensus protocol: k rounds contract the weight
    spread by ~(1 - spectral_gap)^k; after many rounds all learners agree
    on the initial mean (the fixed point of Eq. 3 with zero gradients)."""
    key = jax.random.PRNGKey(seed)
    t = {"w": jax.random.normal(key, (n, 9))}
    mean0 = learner_mean(t)
    m = topo.ring_matrix(n)
    gap = topo.spectral_gap(m)
    var0 = float(learner_var(t))
    for _ in range(60):
        t = dpsgd.mix_einsum(t, m)
    # consensus reached at (at least) the spectral-gap rate
    assert float(learner_var(t)) <= var0 * (1 - gap) ** 40 + 1e-8
    d = tree_norm_sq(tree_sub(learner_mean(t), mean0))
    assert float(d) < 1e-7
