import jax
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis", reason="install the [test] "
                                 "extra for property-based tests")
from hypothesis import given, settings, strategies as st

from repro.core import topology as topo


@pytest.mark.parametrize("n", [2, 3, 4, 8, 16])
def test_full_ring_torus_doubly_stochastic(n):
    assert topo.is_doubly_stochastic(topo.full_matrix(n))
    assert topo.is_doubly_stochastic(topo.ring_matrix(n))
    r = int(np.sqrt(n))
    while n % r:
        r -= 1
    assert topo.is_doubly_stochastic(topo.torus_matrix(r, n // r))


@given(st.integers(2, 24), st.integers(0, 1000))
@settings(max_examples=25, deadline=None)
def test_random_pair_doubly_stochastic_and_involutive(n, seed):
    m = topo.random_pair_matrix(jax.random.PRNGKey(seed), n)
    assert topo.is_doubly_stochastic(m)
    # pairing: applying the mix twice returns the pair average again (M @ M == M)
    m = np.asarray(m, np.float64)
    assert np.allclose(m @ m, m, atol=1e-6)


def test_spectral_gap_ordering():
    # full averaging mixes fastest, ring slowest, random-pair in between
    n = 16
    g_full = topo.spectral_gap(topo.full_matrix(n))
    g_ring = topo.spectral_gap(topo.ring_matrix(n))
    assert g_full > g_ring > 0


def test_hierarchical_matrix_rows():
    m = topo.hierarchical_matrix(4, 2)
    assert topo.is_doubly_stochastic(m)


def test_make_mixing_fn_shapes():
    for name in ["full", "ring", "torus", "random_pair", "solo",
                 "hierarchical", "exp"]:
        fn = topo.make_mixing_fn(name, 8)
        m = fn(jax.random.PRNGKey(0))
        assert m.shape == (8, 8)
        assert topo.is_doubly_stochastic(m)
    with pytest.raises(ValueError):
        topo.make_mixing_fn("nope", 8)


@pytest.mark.parametrize("n", [2, 3, 4, 8, 13])
def test_exponential_matrix_doubly_stochastic_circulant(n):
    m = np.asarray(topo.exponential_matrix(n), np.float64)
    assert topo.is_doubly_stochastic(m)
    # circulant: every row is the first row shifted
    for i in range(n):
        np.testing.assert_allclose(m[i], np.roll(m[0], i), atol=1e-7)
