import jax
import jax.numpy as jnp
import numpy as np

from repro.core import AlgoConfig, MultiLearnerTrainer
from repro.core.diagnostics import compute_diagnostics
from repro.optim import sgd


def quad_loss(params, batch):
    # L(w) = 0.5 ||w - mu_batch||^2 ; grad = w - mu
    return 0.5 * jnp.sum((params["w"] - jnp.mean(batch["x"], 0)) ** 2)


def test_alpha_e_equals_alpha_for_identical_weights():
    """With all learners at the SAME weights and the same batch, g_a == g so
    alpha_e == alpha and Delta2 == 0 (DPSGD degenerates to SSGD)."""
    n, d = 4, 16
    w = jax.random.normal(jax.random.PRNGKey(0), (d,))
    params = {"w": jnp.broadcast_to(w, (n, d))}
    x = jnp.zeros((n, 8, d))
    stats = compute_diagnostics(quad_loss, params, {"x": x}, alpha=0.3)
    np.testing.assert_allclose(float(stats.alpha_e), 0.3, rtol=1e-5)
    assert float(stats.delta_2) < 1e-10
    assert float(stats.sigma_w_sq) < 1e-12


def test_sigma_w_matches_variance():
    n, d = 8, 32
    ws = jax.random.normal(jax.random.PRNGKey(1), (n, d))
    stats = compute_diagnostics(quad_loss, {"w": ws},
                                {"x": jnp.zeros((n, 4, d))}, alpha=1.0)
    expected = float(jnp.sum(jnp.var(ws, axis=0)))
    np.testing.assert_allclose(float(stats.sigma_w_sq), expected, rtol=1e-5)


def test_delta2_zero_for_quadratic_loss():
    """For a quadratic loss gradients are LINEAR in w, so the per-learner
    deviations cancel in the mean: Delta2 == 0 exactly (Eq. 5 needs varying
    curvature to be non-zero)."""
    n, d = 4, 16
    ws = jax.random.normal(jax.random.PRNGKey(2), (n, d))
    stats = compute_diagnostics(quad_loss, {"w": ws},
                                {"x": jnp.zeros((n, 4, d))}, alpha=1.0)
    assert float(stats.delta_2) < 1e-10


def test_delta2_positive_for_nonquadratic_loss():
    def quartic(params, batch):
        return 0.25 * jnp.sum(params["w"] ** 4) + 0.0 * jnp.sum(batch["x"])
    n, d = 4, 16
    ws = jax.random.normal(jax.random.PRNGKey(2), (n, d))
    stats = compute_diagnostics(quartic, {"w": ws},
                                {"x": jnp.zeros((n, 4, d))}, alpha=1.0)
    assert float(stats.delta_2) > 1e-4


def test_delta_s_analytic_on_quadratic():
    """App. B normalization: Delta_S = alpha^2 sigma_mb^2 / n with the
    UNBIASED sample estimate of the minibatch-gradient variance, i.e.
    Delta_S = alpha^2 sum_j ||g_j(w_a) - g0||^2 / (n (n-1)).

    For the quadratic L = 0.5||w - mu_batch||^2 the minibatch gradient at
    w_a is w_a - mu_j, so the deviations are exactly mu_bar - mu_j and
    Delta_S is known in closed form from the batch means alone.
    """
    n, d, alpha = 4, 16, 0.3
    key = jax.random.PRNGKey(3)
    ws = jax.random.normal(key, (n, d))
    x = jax.random.normal(jax.random.fold_in(key, 1), (n, 8, d))
    stats = compute_diagnostics(quad_loss, {"w": ws}, {"x": x}, alpha=alpha)
    mus = jnp.mean(x, axis=1)                      # (n, d) minibatch means
    dev = mus - jnp.mean(mus, axis=0, keepdims=True)
    expected = alpha ** 2 * float(jnp.sum(dev ** 2)) / (n * (n - 1))
    np.testing.assert_allclose(float(stats.delta_s), expected, rtol=1e-5)


def test_trainer_diag_shapes():
    def loss_fn(p, b):
        return jnp.mean((b["x"] @ p["w"]) ** 2)
    n = 4
    tr = MultiLearnerTrainer(loss_fn, sgd(0.01), AlgoConfig(n_learners=n),
                             alpha_for_diag=0.01)
    st = tr.init(jax.random.PRNGKey(0), {"w": jnp.ones((8, 2)) * 0.1})
    batch = {"x": jax.random.normal(jax.random.PRNGKey(1), (n, 16, 8))}
    d = tr.diagnostics(st, batch)
    for f in d:
        assert jnp.ndim(f) == 0 and bool(jnp.isfinite(f))
