from repro.launch.roofline import Roofline, _shape_bytes, parse_collectives

HLO = """
HloModule test

%region_body.1 (arg: f32[16,1024]) -> f32[16,1024] {
  %ar = f32[16,1024]{1,0} all-reduce(f32[16,1024]{1,0} %x), replica_groups={{0,1,2,3}}, to_apply=%add
  ROOT %t = f32[16,1024]{1,0} add(%ar, %ar)
}

ENTRY %main (p0: f32[32,512]) -> f32[32,512] {
  %ag = f32[32,512]{1,0} all-gather(f32[8,512]{1,0} %p0), replica_groups={{0,1,2,3}}, dimensions={0}
  %cp = f32[32,512]{1,0} collective-permute(f32[32,512]{1,0} %ag), source_target_pairs={{0,1},{1,0}}
  ROOT %w = f32[32,512]{1,0} while(%cp), body=%region_body.1, condition=%cond
}
"""


def test_shape_bytes():
    assert _shape_bytes("f32[16,1024]") == 16 * 1024 * 4
    assert _shape_bytes("bf16[8]") == 16
    assert _shape_bytes("pred[4,4]") == 16


def test_parse_collectives_trip_count():
    colls = parse_collectives(HLO, body_trip_count=12)
    kinds = {c["kind"]: c for c in colls}
    # all-reduce inside the while body gets x12
    ar = kinds["all-reduce"]
    assert ar["in_loop_body"] and ar["trip_mult"] == 12
    assert ar["link_bytes"] == 2 * 16 * 1024 * 4 * (3 / 4) * 12
    ag = kinds["all-gather"]
    assert not ag["in_loop_body"]
    assert ag["link_bytes"] == 32 * 512 * 4 * (3 / 4)
    cp = kinds["collective-permute"]
    assert cp["link_bytes"] == 32 * 512 * 4


def test_roofline_bottleneck():
    r = Roofline(flops=1e12, hbm_bytes=1e9, link_bytes=1e9, collectives=[])
    assert r.bottleneck == "collective"  # 1e9/50e9 > 1e9/819e9 > 1e12/197e12
    r2 = Roofline(flops=1e15, hbm_bytes=1e9, link_bytes=1e9, collectives=[])
    assert r2.bottleneck == "compute"
