"""shard_map MoE backend == einsum-dispatch oracle on a small forced-device
mesh (subprocess: needs its own XLA device count)."""
import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.models.moe import init_moe_params, moe_forward
from repro.models.moe_shardmap import moe_forward_shardmap

mesh = jax.make_mesh((2, 4), ("data", "model"))
E, k, d, f = 8, 2, 16, 32
B, S = 4, 8
key = jax.random.PRNGKey(0)
params = init_moe_params(key, d, f, E, jnp.float32)
x = jax.random.normal(jax.random.PRNGKey(1), (B, S, d))

# big capacity so neither backend drops -> outputs must match exactly
with mesh:
    y_sm = jax.jit(lambda p, xx: moe_forward_shardmap(
        p, xx, n_experts=E, top_k=k, capacity_factor=64.0))(params, x)
y_ref = moe_forward(params, x, n_experts=E, top_k=k, capacity_factor=64.0)
err = float(jnp.abs(y_sm - y_ref).max())

# gradient path
with mesh:
    g = jax.jit(jax.grad(lambda p, xx: jnp.sum(moe_forward_shardmap(
        p, xx, n_experts=E, top_k=k, capacity_factor=64.0) ** 2)))(params, x)
gnorm = float(sum(jnp.abs(l).sum() for l in jax.tree_util.tree_leaves(g)))
print(json.dumps({"err": err, "gnorm_finite": bool(np.isfinite(gnorm)),
                  "g_router": float(jnp.abs(g["router"]).sum())}))
"""


@pytest.fixture(scope="module")
def result():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, env=env, timeout=600,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert r.returncode == 0, r.stderr[-3000:]
    return json.loads(r.stdout.strip().splitlines()[-1])


def test_matches_einsum_oracle(result):
    assert result["err"] < 1e-4, result


def test_grads_flow(result):
    assert result["gnorm_finite"]
    assert result["g_router"] > 0
