import jax.numpy as jnp
import numpy as np

from repro.optim import (adam, apply_updates, constant_schedule, lamb,
                         linear_warmup, scale_by_schedule, sgd, step_decay,
                         warmup_linear_scale)


def test_sgd_plain():
    opt = sgd(0.1)
    p = {"w": jnp.ones((3,))}
    g = {"w": jnp.full((3,), 2.0)}
    s = opt.init(p)
    u, s = opt.update(g, s, p)
    np.testing.assert_allclose(np.asarray(u["w"]), -0.2, rtol=1e-6)


def test_sgd_momentum_accumulates():
    opt = sgd(1.0, momentum=0.5)
    p = {"w": jnp.zeros((1,))}
    g = {"w": jnp.ones((1,))}
    s = opt.init(p)
    u1, s = opt.update(g, s, p)   # mu=1      -> u=-1
    u2, s = opt.update(g, s, p)   # mu=1.5    -> u=-1.5
    assert float(u1["w"][0]) == -1.0
    assert float(u2["w"][0]) == -1.5


def test_adam_first_step_is_lr_sized():
    opt = adam(0.01)
    p = {"w": jnp.zeros((4,))}
    g = {"w": jnp.array([1.0, -1.0, 10.0, -0.1])}
    s = opt.init(p)
    u, s = opt.update(g, s, p)
    np.testing.assert_allclose(np.abs(np.asarray(u["w"])), 0.01, rtol=1e-3)


def test_lamb_trust_ratio_scales():
    opt = lamb(0.1, weight_decay=0.0)
    p = {"w": jnp.full((4,), 10.0)}     # big weights -> big trust ratio
    g = {"w": jnp.full((4,), 1.0)}
    s = opt.init(p)
    u, _ = opt.update(g, s, p)
    # trust = ||p|| / ||adam_step|| = 20 / 2 = 10 -> update = -0.1*10*1
    np.testing.assert_allclose(np.asarray(u["w"]), -1.0, rtol=1e-2)


def test_apply_updates():
    p = {"w": jnp.ones((2,))}
    out = apply_updates(p, {"w": jnp.full((2,), 0.5)})
    np.testing.assert_allclose(np.asarray(out["w"]), 1.5)


def test_schedules():
    s = linear_warmup(10, peak=1.0)
    assert float(s(jnp.int32(0))) == 0.0
    assert float(s(jnp.int32(10))) == 1.0
    d = step_decay([5, 10], [1.0, 0.1, 0.01])
    assert abs(float(d(jnp.int32(0))) - 1.0) < 1e-6
    assert abs(float(d(jnp.int32(7))) - 0.1) < 1e-6
    assert abs(float(d(jnp.int32(20))) - 0.01) < 1e-6
    w = warmup_linear_scale(4, 8.0, anneal_boundaries=(100,))
    assert float(w(jnp.int32(0))) == 1.0
    assert float(w(jnp.int32(4))) == 8.0
    assert abs(float(w(jnp.int32(200))) - 0.8) < 1e-6


def test_scale_by_schedule_composes():
    opt = scale_by_schedule(sgd(1.0), constant_schedule(0.5))
    p = {"w": jnp.zeros((1,))}
    s = opt.init(p)
    u, s = opt.update({"w": jnp.ones((1,))}, s, p)
    assert float(u["w"][0]) == -0.5
    assert int(s["step"]) == 1
