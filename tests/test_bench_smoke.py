"""Every benchmarks/*.py workload must expose and survive its ``--smoke``
entrypoint (ISSUE 6): the smoke sweep is what `make bench-check` and CI
gate on, so a workload whose CLI rots breaks the bench matrix silently.

Results are redirected to a tmp dir via REPRO_BENCH_RESULTS so the sweep
never clobbers a real ``results/bench`` run.  Guards follow the existing
importorskip pattern (tests/test_properties.py): a trimmed environment
skips instead of erroring.
"""
import glob
import importlib
import os

import pytest

pytest.importorskip("jax", reason="benchmark workloads train through jax")

BENCH_DIR = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "benchmarks")

# every module under benchmarks/ that is a runnable workload (has a
# --smoke CLI and prints a `name,us_per_call,derived` row or, for matrix,
# emits the BENCH_PR<N>.json artifact)
WORKLOADS = (
    "fig2_effective_lr",
    "fig3_straggler",
    "fig4_noise_decomp",
    "table1_large_batch",
    "table4_lr_tuning",
    "table5_asr_proxy",
    "theorem1_smoothing",
    "ablation_topology",
    "bench_kernels",
    "bench_throughput",
    "faults",
    "roofline_report",
    "serving",
    "matrix",
)
# gates/libraries, not workloads: no training entrypoint of their own
NON_WORKLOADS = {"run", "common", "schema", "trajectory",
                 "check_contract", "check_regression", "__init__"}


def test_workload_list_is_complete():
    """A new benchmarks/*.py must either join WORKLOADS (and support
    --smoke) or be declared a non-workload here — no silent third state."""
    modules = {os.path.basename(p)[:-3]
               for p in glob.glob(os.path.join(BENCH_DIR, "*.py"))}
    assert modules == set(WORKLOADS) | (modules & NON_WORKLOADS), (
        "unclassified benchmarks module(s): "
        f"{modules - set(WORKLOADS) - NON_WORKLOADS}")


@pytest.fixture()
def bench_tmp_results(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_BENCH_RESULTS", str(tmp_path))
    return tmp_path


# `matrix` is exercised (and its artifact schema-checked) by the dedicated
# test below — running its full cell sweep twice would double CI cost
@pytest.mark.parametrize("name", [w for w in WORKLOADS if w != "matrix"])
def test_workload_survives_smoke(name, bench_tmp_results, capsys):
    mod = importlib.import_module(f"benchmarks.{name}")
    rc = mod.main(["--smoke"])
    # mains return either an exit code (matrix-style) or a result payload
    # (fig2 returns its losses dict); only int exit codes can fail
    assert not (isinstance(rc, int) and rc), f"{name} --smoke exited {rc}"
    out = capsys.readouterr().out
    # bench_kernels prints per-kernel rows: bench_kernel_<name>;
    # serving's summary row matches its results table (bench_serving.csv)
    stem = {"bench_kernels": "bench_kernel",
            "serving": "bench_serving",
            "faults": "bench_faults"}.get(name, name)
    assert any(line.startswith(stem) for line in out.splitlines()), (
        f"{name} --smoke printed no `{stem},us,derived` contract row:\n"
        f"{out}")


def test_matrix_smoke_artifact_is_schema_valid(bench_tmp_results, capsys):
    from benchmarks import matrix, schema
    assert matrix.main(["--smoke", "--pr", "6"]) == 0
    out = capsys.readouterr().out
    assert any(line.startswith("bench_matrix,")
               for line in out.splitlines()), out
    path = bench_tmp_results / "BENCH_PR6.json"
    payload = schema.load_result(str(path))
    assert payload["pr"] == 6 and not payload.get("legacy")
    expected = {schema.cell_key(c)
                for c in matrix.expand(matrix.SPEC, smoke=True)}
    assert set(payload["cells"]) == expected
    # matrix throughput cells must align with the committed legacy history
    hist = schema.load_result(os.path.join(
        BENCH_DIR, "history", "BENCH_PR3.json"))
    shared = set(payload["cells"]) & set(hist["cells"])
    assert len(shared) >= 6, (sorted(payload["cells"]), sorted(hist["cells"]))
