"""Smoke tests for examples/: run the main paths for a few steps under tiny
configs so the examples can't silently rot (imports, API drift, shape bugs).

The example scripts are not a package; they are loaded by file path.  Each
test is importorskip-guarded on the example's dependencies so a trimmed
environment skips instead of erroring.
"""
import importlib.util
import os
import sys

import pytest

EXAMPLES = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "examples")


def _load(name):
    spec = importlib.util.spec_from_file_location(
        f"examples_{name}", os.path.join(EXAMPLES, f"{name}.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_quickstart_main_path():
    pytest.importorskip("jax")
    qs = _load("quickstart")
    ssgd, dpsgd = qs.main(steps=4, local_batch=16)
    assert ssgd == ssgd and dpsgd == dpsgd   # finite (not nan) after 4 steps


def test_serve_batched_main_path(monkeypatch, capsys):
    pytest.importorskip("jax")
    sb = _load("serve_batched")
    monkeypatch.setattr(sys, "argv",
                        ["serve_batched.py", "--arch", "transformer-100m",
                         "--batch", "2", "--new-tokens", "3", "--buf", "16"])
    sb.main()
    out = capsys.readouterr().out
    assert "tok/s aggregate" in out
    assert "sequences:" in out
    # the example now drives the serve engine: slots + request accounting
    assert "slots=2" in out and "requests=4" in out
    # 4 requests x 3 new tokens, every token counted (incl. the first)
    assert "12 tokens" in out
