"""Flat-state engine contracts (DESIGN §11).

Pins the tentpole guarantees of PR 3:
  * parity — the flat fused engine reproduces the pytree reference for
    SSGD / DPSGD / AD-PSGD (params, momentum, metrics), for both kernel
    backends;
  * the lax.scan driver == k sequential steps, optimizer state included
    (momentum AND controller scale round-trip);
  * the traced step carries no parameter-sized concatenate (the per-step
    re-flatten is gone) and never retraces across steps/scale writes;
  * train_step donates its state (buffers reused in place).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import AlgoConfig, MultiLearnerTrainer
from repro.core.flatstate import LANE, flat_meta, max_concat_elems
from repro.data import ShardedLoader, TemplateImages
from repro.models import fcnet
from repro.optim import (constant_schedule, controller_scale,
                         scale_by_controller, scale_by_schedule,
                         set_controller_scale, sgd)

N = 5
DS = TemplateImages()
LOADER = ShardedLoader(DS, n_learners=N, local_batch=64, seed=0)
PARAMS = fcnet.init_params(jax.random.PRNGKey(0), in_dim=784, hidden=50)
ADPSGD_KW = dict(max_staleness=4, slow_learner=0, slow_factor=3)


def _trainer(algo, engine, opt=None, backend="auto", topology="random_pair",
             **kw):
    return MultiLearnerTrainer(
        fcnet.loss_fn, opt or sgd(0.1, momentum=0.9),
        AlgoConfig(algo=algo, topology=topology, n_learners=N, **kw),
        engine=engine, kernel_backend=backend)


def _train(tr, steps, seed=0):
    st = tr.init(jax.random.PRNGKey(seed), PARAMS)
    losses = []
    for i in range(steps):
        st, m = tr.train_step(st, LOADER.batch(i))
        losses.append(float(m.loss))
    return st, losses


# ---------------------------------------------------------------------------
# flat store
# ---------------------------------------------------------------------------

def test_flat_meta_roundtrip_dtypes_and_padding():
    tree = {"a": jnp.arange(10.0), "b": {"c": jnp.ones((3, 5), jnp.bfloat16)},
            "d": jnp.float32(2.0)}
    meta = flat_meta(tree)
    assert meta.rows % 8 == 0
    flat = meta.flatten(tree)
    assert flat.shape == (meta.rows, LANE) and flat.dtype == jnp.float32
    back = meta.unflatten(flat)
    assert back["b"]["c"].dtype == jnp.bfloat16    # per-leaf dtype preserved
    np.testing.assert_array_equal(np.asarray(back["a"]), np.arange(10.0))
    assert float(back["d"]) == 2.0
    # stacked leading axis
    stacked = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(jnp.asarray(x)[None],
                                   (4,) + jnp.shape(x)), tree)
    fs = meta.flatten(stacked)
    assert fs.shape == (4, meta.rows, LANE)
    np.testing.assert_array_equal(
        np.asarray(meta.unflatten(fs)["a"]), np.asarray(stacked["a"]))
    # meta is cached per structure
    assert flat_meta(tree) is meta


def test_flat_meta_scatter_is_unflatten_transpose():
    meta = flat_meta(PARAMS)
    flat = meta.flatten(PARAMS)
    np.testing.assert_array_equal(
        np.asarray(meta.scatter(meta.unflatten(flat))), np.asarray(flat))


# ---------------------------------------------------------------------------
# engine parity (satellite: SSGD / DPSGD / AD-PSGD, both kernel backends)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algo,kw", [("ssgd", {}), ("dpsgd", {}),
                                     ("adpsgd", ADPSGD_KW)])
@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_flat_matches_pytree(algo, kw, backend):
    steps = 12
    st_t, l_t = _train(_trainer(algo, "pytree", **kw), steps)
    tr_f = _trainer(algo, "flat", backend=backend, **kw)
    st_f, l_f = _train(tr_f, steps)
    assert st_f.params.shape == (N, tr_f._meta.rows, LANE)
    view = tr_f.state_view(st_f)
    for k in st_t.params:
        np.testing.assert_allclose(np.asarray(view.params[k]),
                                   np.asarray(st_t.params[k]),
                                   atol=2e-5, rtol=2e-5)
        np.testing.assert_allclose(np.asarray(view.opt_state["mu"][k]),
                                   np.asarray(st_t.opt_state["mu"][k]),
                                   atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(l_f, l_t, atol=1e-5)
    if algo == "adpsgd":
        np.testing.assert_array_equal(np.asarray(st_f.age),
                                      np.asarray(st_t.age))
        np.testing.assert_array_equal(np.asarray(st_f.clock),
                                      np.asarray(st_t.clock))


def test_dpsgd_defaults_to_flat_fused_engine():
    """Acceptance: MultiLearnerTrainer(algo='dpsgd') IS the flat fused
    engine; SSGD keeps the reference layout."""
    tr = _trainer("dpsgd", "auto")
    assert tr.is_flat and tr._fused is not None
    tr_a = _trainer("adpsgd", "auto", **ADPSGD_KW)
    assert tr_a.is_flat and tr_a._fused is not None
    assert not _trainer("ssgd", "auto").is_flat
    with pytest.raises(ValueError):
        _trainer("ssgd_star", "flat")


def test_flat_ring_topology_fused():
    tr_f = _trainer("dpsgd", "flat", topology="ring")
    assert tr_f._fused is not None
    st_f, l_f = _train(tr_f, 8)
    st_t, l_t = _train(_trainer("dpsgd", "pytree", topology="ring"), 8)
    view = tr_f.state_view(st_f)
    for k in st_t.params:
        np.testing.assert_allclose(np.asarray(view.params[k]),
                                   np.asarray(st_t.params[k]), atol=2e-5)


# every compiled schedule (n=8: torus 2x4, hypercube full, 2-level
# hierarchical, exponential graphs, multi-round matching) must dispatch the
# fused kernel AND reproduce the pytree reference — the PR 4 acceptance bar
N8 = 8
LOADER8 = ShardedLoader(DS, n_learners=N8, local_batch=40, seed=0)
SCHEDULED = ["ring", "torus", "full", "hierarchical", "exp", "one_peer_exp",
             "random_matching"]


def _trainer8(engine, topology, backend="auto", **kw):
    return MultiLearnerTrainer(
        fcnet.loss_fn, sgd(0.1, momentum=0.9),
        AlgoConfig(algo="dpsgd", topology=topology, n_learners=N8, **kw),
        engine=engine, kernel_backend=backend)


def _train8(tr, steps):
    st = tr.init(jax.random.PRNGKey(0), PARAMS)
    for i in range(steps):
        st, m = tr.train_step(st, LOADER8.batch(i))
    return st


@pytest.mark.parametrize("topology", SCHEDULED)
def test_every_scheduled_topology_dispatches_fused_kernel(topology):
    """Acceptance: no scheduled topology falls back to the generic path,
    and the fused step tracks the pytree engine on params AND momentum
    across the full schedule period (6 steps covers every cycle here)."""
    kw = {"gossip_rounds": 2} if topology == "random_matching" else {}
    tr_f = _trainer8("auto", topology, **kw)
    assert tr_f.is_flat and tr_f._fused is not None, topology
    st_f = _train8(tr_f, 6)
    st_t = _train8(_trainer8("pytree", topology, **kw), 6)
    view = tr_f.state_view(st_f)
    for k in st_t.params:
        np.testing.assert_allclose(np.asarray(view.params[k]),
                                   np.asarray(st_t.params[k]),
                                   atol=2e-5, rtol=2e-5, err_msg=topology)
        np.testing.assert_allclose(np.asarray(view.opt_state["mu"][k]),
                                   np.asarray(st_t.opt_state["mu"][k]),
                                   atol=2e-5, rtol=2e-5, err_msg=topology)


@pytest.mark.parametrize("topology,kw", [("hierarchical", {}), ("full", {}),
                                         ("random_matching",
                                          {"gossip_rounds": 2})])
def test_multi_round_schedule_weight_decay_parity(topology, kw):
    """Regression: weight decay regularizes the PRE-mix local weights.  On
    a multi-round schedule the leading mix rounds overwrite the flat buffer
    before the fused update, so a kernel-side decay would act on the MIXED
    weights — the trainer folds the decay into the gradients instead, and
    fused must track pytree as tightly as the decay-free runs."""
    opt = sgd(0.1, momentum=0.9, weight_decay=0.1)
    tr_f = MultiLearnerTrainer(
        fcnet.loss_fn, opt,
        AlgoConfig(algo="dpsgd", topology=topology, n_learners=N8, **kw),
        engine="flat")
    assert tr_f._fused is not None and len(
        tr_f._schedule.step_rounds(jax.random.PRNGKey(0), 0)) > 1
    st_f = _train8(tr_f, 6)
    st_t = _train8(MultiLearnerTrainer(
        fcnet.loss_fn, opt,
        AlgoConfig(algo="dpsgd", topology=topology, n_learners=N8, **kw),
        engine="pytree"), 6)
    view = tr_f.state_view(st_f)
    for k in st_t.params:
        np.testing.assert_allclose(np.asarray(view.params[k]),
                                   np.asarray(st_t.params[k]),
                                   atol=2e-5, rtol=2e-5, err_msg=topology)


def test_gossip_rounds_only_valid_for_random_matching():
    AlgoConfig(algo="dpsgd", topology="random_matching", n_learners=8,
               gossip_rounds=3)
    with pytest.raises(AssertionError):
        AlgoConfig(algo="dpsgd", topology="ring", n_learners=8,
                   gossip_rounds=3)
    with pytest.raises(AssertionError):
        AlgoConfig(algo="dpsgd", topology="random_pair", n_learners=8,
                   gossip_rounds=3)


@pytest.mark.parametrize("topology", ["torus", "one_peer_exp"])
def test_scheduled_topology_pallas_backend_parity(topology):
    """The Mosaic kernel (interpret mode on CPU) agrees with the oracle
    backend on a K=4 static schedule and a time-varying K=1 one."""
    st_p = _train8(_trainer8("flat", topology, backend="pallas"), 4)
    st_r = _train8(_trainer8("flat", topology, backend="ref"), 4)
    np.testing.assert_allclose(np.asarray(st_p.params),
                               np.asarray(st_r.params), atol=1e-5)


def test_engine_auto_falls_back_cleanly_where_kernel_cannot_express():
    """Topologies/configs the fused kernel cannot express run the generic
    flat path with no crash and full pytree parity.  torus/hierarchical were
    the positive controls before they gained kernel support — now they are
    regression-pinned as fused (test above); the remaining unexpressible
    cases are the non-paper gossip ordering and a wants_mixed optimizer."""
    from repro.optim import decentlam
    # descend_then_mix: the kernel bakes in the paper Eq. 2 ordering
    tr = _trainer8("auto", "torus", gossip_order="descend_then_mix")
    assert tr.is_flat and tr._fused is None
    st_f = _train8(tr, 5)
    tr_t = _trainer8("pytree", "torus", gossip_order="descend_then_mix")
    st_t = _train8(tr_t, 5)
    view = tr.state_view(st_f)
    for k in st_t.params:
        np.testing.assert_allclose(np.asarray(view.params[k]),
                                   np.asarray(st_t.params[k]), atol=2e-5)
    # wants_mixed (decentlam) needs the unfused update — clean generic path
    opt = decentlam(0.05, momentum=0.9)
    tr2 = MultiLearnerTrainer(
        fcnet.loss_fn, opt,
        AlgoConfig(algo="dpsgd", topology="hierarchical", n_learners=N8),
        engine="auto")
    assert tr2.is_flat and tr2._fused is None
    st2 = tr2.init(jax.random.PRNGKey(0), PARAMS)
    st2, m = tr2.train_step(st2, LOADER8.batch(0))
    assert bool(jnp.isfinite(m.loss))
    # solo has no schedule: generic path, no crash
    tr3 = _trainer8("auto", "solo")
    assert tr3._fused is None
    _train8(tr3, 2)


def test_layout_sensitive_optimizer_stays_on_pytree_engine():
    """lamb's layer-wise trust ratio would silently collapse on the single
    flat leaf: auto must pick the pytree engine, explicit flat must raise."""
    from repro.optim import lamb
    tr = _trainer("dpsgd", "auto", opt=lamb(0.01))
    assert not tr.is_flat
    with pytest.raises(ValueError):
        _trainer("dpsgd", "flat", opt=lamb(0.01))


def test_state_view_roundtrip():
    """state_from_view(state_view(s)) == s bitwise — the checkpoint
    layout-portability contract (params, momentum, scalars)."""
    from repro.optim import scale_by_controller
    tr = _trainer("adpsgd", "flat", opt=scale_by_controller(
        sgd(0.1, momentum=0.9)), **ADPSGD_KW)
    st, _ = _train(tr, 5)
    back = tr.state_from_view(tr.state_view(st))
    np.testing.assert_array_equal(np.asarray(back.params),
                                  np.asarray(st.params))
    np.testing.assert_array_equal(np.asarray(back.buffer),
                                  np.asarray(st.buffer))
    for a, b in zip(jax.tree_util.tree_leaves(back.opt_state),
                    jax.tree_util.tree_leaves(st.opt_state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_flat_unfused_optimizer_falls_back():
    """A non-SGD optimizer (nesterov here) still runs the flat engine via
    the generic path — no fused kernel, same results as pytree."""
    opt = sgd(0.1, momentum=0.9, nesterov=True)
    assert opt.fused is None
    tr_f = _trainer("dpsgd", "flat", opt=opt)
    assert tr_f._fused is None
    st_f, _ = _train(tr_f, 8)
    st_t, _ = _train(_trainer("dpsgd", "pytree", opt=opt), 8)
    view = tr_f.state_view(st_f)
    for k in st_t.params:
        np.testing.assert_allclose(np.asarray(view.params[k]),
                                   np.asarray(st_t.params[k]), atol=2e-5)


# ---------------------------------------------------------------------------
# scan driver + opt-state round-trip
# ---------------------------------------------------------------------------

def test_run_steps_matches_sequential_with_controller_scale():
    """lax.scan(k) == k sequential train_steps, opt state included: momentum
    buffers AND the AutoLR controller scale survive the scan round-trip."""
    opt = scale_by_controller(scale_by_schedule(sgd(0.1, momentum=0.9),
                                                constant_schedule(1.0)))
    k = 7
    batches = [LOADER.batch(i) for i in range(k)]
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *batches)

    tr1 = _trainer("dpsgd", "flat", opt=opt)
    tr2 = _trainer("dpsgd", "flat", opt=opt)
    st1 = tr1.init(jax.random.PRNGKey(0), PARAMS)
    st2 = tr2.init(jax.random.PRNGKey(0), PARAMS)
    st1 = st1._replace(opt_state=set_controller_scale(st1.opt_state, 0.7))
    st2 = st2._replace(opt_state=set_controller_scale(st2.opt_state, 0.7))

    st1, ms = tr1.run_steps(st1, stacked, k=k)
    for b in batches:
        st2, _ = tr2.train_step(st2, b)

    assert ms.loss.shape == (k,)
    np.testing.assert_allclose(np.asarray(st1.params),
                               np.asarray(st2.params), atol=1e-6)
    np.testing.assert_allclose(
        np.asarray(tr1._fused.read_mu(st1.opt_state)),
        np.asarray(tr2._fused.read_mu(st2.opt_state)), atol=1e-6)
    np.testing.assert_allclose(np.asarray(controller_scale(st1.opt_state)),
                               0.7, rtol=1e-6)
    assert int(st1.step) == k


def test_run_steps_validates_k():
    tr = _trainer("dpsgd", "flat")
    st = tr.init(jax.random.PRNGKey(0), PARAMS)
    stacked = jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs), *[LOADER.batch(i) for i in range(3)])
    with pytest.raises(ValueError):
        tr.run_steps(st, stacked, k=5)


# ---------------------------------------------------------------------------
# tracing guards: no param-sized concat, no retrace, state donation
# ---------------------------------------------------------------------------

def test_no_param_sized_concatenate_in_flat_step():
    """The flatten happens once at init: the traced step (and the whole
    scan driver) may only contain RNG-sized concats.  The old per-call
    wrapper is the positive control for the checker."""
    tr = _trainer("dpsgd", "flat")
    st = tr.init(jax.random.PRNGKey(0), PARAMS)
    batch = LOADER.batch(0)
    n_elem = tr._meta.n_elem
    assert max_concat_elems(
        jax.make_jaxpr(tr._train_step)(st, batch)) < n_elem // 100

    stacked = jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs), *[LOADER.batch(i) for i in range(3)])
    assert max_concat_elems(
        jax.make_jaxpr(tr._run_steps)(st, stacked)) < n_elem // 100

    # positive control: the per-call flatten wrapper DOES concatenate
    from repro.kernels.ops import dpsgd_fused_update
    mu = jax.tree_util.tree_map(jnp.zeros_like, PARAMS)
    jxp = jax.make_jaxpr(lambda a, g, m: dpsgd_fused_update(
        a, [a], g, m, [0.5, 0.5], lr=0.1))(PARAMS, PARAMS, mu)
    assert max_concat_elems(jxp) >= n_elem


def test_no_retrace_across_steps_and_scale_writes():
    """Compile-count guard: stepping and writing the controller scale must
    reuse the ONE compiled executable (scale lives in opt state)."""
    tr = _trainer("dpsgd", "flat", opt=scale_by_controller(sgd(0.1)))
    st = tr.init(jax.random.PRNGKey(0), PARAMS)
    for i in range(3):
        st, _ = tr.train_step(st, LOADER.batch(i))
    st = st._replace(opt_state=set_controller_scale(st.opt_state, 0.5))
    for i in range(3, 6):
        st, _ = tr.train_step(st, LOADER.batch(i))
    assert tr.train_step._cache_size() == 1
    # pytree engine gets the same guarantee
    tr2 = _trainer("ssgd", "pytree", opt=scale_by_controller(sgd(0.1)))
    st2 = tr2.init(jax.random.PRNGKey(0), PARAMS)
    for i in range(2):
        st2, _ = tr2.train_step(st2, LOADER.batch(i))
    st2 = st2._replace(opt_state=set_controller_scale(st2.opt_state, 0.5))
    st2, _ = tr2.train_step(st2, LOADER.batch(2))
    assert tr2.train_step._cache_size() == 1


def test_train_step_donates_state():
    """donate_argnums is live: a consumed state's buffers are gone (the
    engine updates them in place — reuse is a bug, and jax says so)."""
    tr = _trainer("dpsgd", "flat")
    st0 = tr.init(jax.random.PRNGKey(0), PARAMS)
    st1, _ = tr.train_step(st0, LOADER.batch(0))
    with pytest.raises(RuntimeError):
        jax.block_until_ready(st0.params + 0)


# ---------------------------------------------------------------------------
# probe seam + views on the flat engine
# ---------------------------------------------------------------------------

def test_probe_hooks_see_pytree_view_and_controller_writes_flat_state():
    from repro.landscape import ProbeSchedule
    tr = _trainer("dpsgd", "flat", opt=scale_by_controller(sgd(0.1)))
    seen = {}

    def probe(state, batch):
        seen["params"] = state.params          # must be the pytree view
        return 0.5

    tr.add_probe("p", ProbeSchedule(every=1), probe,
                 on_result=lambda st, r: st._replace(
                     opt_state=set_controller_scale(st.opt_state, r)))
    st = tr.init(jax.random.PRNGKey(0), PARAMS)
    st, results = tr.run_probes(st, LOADER.batch(0), step=0)
    assert results == {"p": 0.5}
    assert set(seen["params"].keys()) == set(PARAMS.keys())
    np.testing.assert_allclose(np.asarray(controller_scale(st.opt_state)),
                               0.5, rtol=1e-6)
    # diagnostics + eval accept the flat state directly
    d = tr.diagnostics(st, LOADER.batch(1))
    assert bool(jnp.isfinite(d.alpha_e))
    ev = tr.eval_loss(st, LOADER.eval_batch(64))
    assert bool(jnp.isfinite(ev))


def test_flat_metrics_match_pytree_metrics():
    tr_t = _trainer("dpsgd", "pytree")
    tr_f = _trainer("dpsgd", "flat")
    st_t = tr_t.init(jax.random.PRNGKey(0), PARAMS)
    st_f = tr_f.init(jax.random.PRNGKey(0), PARAMS)
    for i in range(5):
        st_t, m_t = tr_t.train_step(st_t, LOADER.batch(i))
        st_f, m_f = tr_f.train_step(st_f, LOADER.batch(i))
    np.testing.assert_allclose(float(m_f.loss), float(m_t.loss), atol=1e-5)
    np.testing.assert_allclose(float(m_f.grad_norm), float(m_t.grad_norm),
                               rtol=1e-4)
    np.testing.assert_allclose(float(m_f.sigma_w_sq), float(m_t.sigma_w_sq),
                               rtol=2e-3, atol=1e-9)
