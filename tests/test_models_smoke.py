"""Per-architecture smoke tests (deliverable f): reduced same-family variant,
one forward/train step on CPU, asserting output shapes + no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED, REGISTRY
from repro.models import build_model, make_synthetic_batch

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ASSIGNED)
def test_train_step_smoke(arch):
    cfg = REGISTRY[arch].smoke_config()
    assert cfg.d_model <= 512 and (cfg.n_experts or 4) <= 4
    api = build_model(cfg)
    params = api.init(KEY)
    batch = make_synthetic_batch(cfg, KEY, 2, 64)
    loss, grads = jax.value_and_grad(api.loss_fn)(params, batch)
    assert jnp.ndim(loss) == 0
    assert bool(jnp.isfinite(loss)), f"{arch} loss not finite"
    leaves = jax.tree_util.tree_leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in leaves), f"{arch} grad NaN"
    # logits shape
    logits = api.apply(params, batch)
    assert logits.shape[-1] == cfg.padded_vocab
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", ASSIGNED)
def test_decode_step_smoke(arch):
    cfg = REGISTRY[arch].smoke_config()
    api = build_model(cfg)
    params = api.init(KEY)
    if cfg.family == "audio":
        frames = jnp.zeros((2, 16, cfg.d_model), jnp.float32)
        cache = api.init_cache(params, frames, 32)
    else:
        cache = api.init_cache(params, 2, 32)
    tok = jnp.zeros((2, 1), jnp.int32)
    logits, cache = api.decode_step(params, cache, tok, jnp.int32(0))
    logits2, _ = api.decode_step(params, cache, tok, jnp.int32(1))
    assert logits.shape == (2, 1, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits2).all()), f"{arch} decode NaN"


def test_param_counts_match_analytic():
    """init() parameter count within 20% of the closed-form n_params()
    used by the roofline (catches drift between model and analytics)."""
    for arch in ["yi-34b", "qwen3-moe-235b-a22b", "jamba-v0.1-52b"]:
        cfg = REGISTRY[arch].smoke_config()
        api = build_model(cfg)
        shapes = jax.eval_shape(api.init, KEY)
        real = sum(s.size for s in jax.tree_util.tree_leaves(shapes))
        est = cfg.n_params()
        assert abs(real - est) / real < 0.25, (arch, real, est)
