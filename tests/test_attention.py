import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ref import flash_attention_ref
from repro.models.attention import (attn_decode, attn_forward,
                                    chunked_attention, init_attn_cache,
                                    init_attn_params)


def _qkv(key, B=2, S=64, H=4, KV=2, hd=16):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    return q, k, v


@pytest.mark.parametrize("kw", [dict(causal=True), dict(causal=False),
                                dict(causal=True, window=16),
                                dict(causal=True, attn_softcap=30.0)])
@pytest.mark.parametrize("chunk", [16, 32, 64])
def test_chunked_matches_dense(kw, chunk):
    q, k, v = _qkv(jax.random.PRNGKey(0))
    pos = jnp.arange(64)
    out = chunked_attention(q, k, v, q_positions=pos, k_positions=pos,
                            chunk=chunk, **kw)
    ref = flash_attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                              v.transpose(0, 2, 1, 3), **kw)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.transpose(0, 2, 1, 3)),
                               atol=2e-5)


def test_decode_matches_forward():
    """Prefill-decode consistency: token t's decode output equals the
    training forward at position t (global attention, same params)."""
    B, S, H, KV, hd, d = 1, 12, 4, 2, 8, 32
    key = jax.random.PRNGKey(1)
    params = init_attn_params(key, d, H, KV, hd, jnp.float32)
    x = jax.random.normal(key, (B, S, d))
    pos = jnp.arange(S)
    rope = lambda t, p: t  # no rope: isolates cache logic
    full = attn_forward(params, x, n_heads=H, n_kv=KV, head_dim=hd,
                        rope_fn=rope, q_positions=pos, chunk=S)
    cache = init_attn_cache(B, S, KV, hd, jnp.float32)
    outs = []
    for t in range(S):
        o, cache = attn_decode(params, cache, x[:, t:t + 1], jnp.int32(t),
                               n_heads=H, n_kv=KV, head_dim=hd, rope_fn=rope)
        outs.append(o)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), atol=1e-4)


def test_decode_rotating_window():
    """With a buffer smaller than the sequence, decode attends over exactly
    the last `buf` tokens (sliding-window serving)."""
    B, S, H, KV, hd, d, buf = 1, 20, 2, 2, 8, 16, 8
    key = jax.random.PRNGKey(2)
    params = init_attn_params(key, d, H, KV, hd, jnp.float32)
    x = jax.random.normal(key, (B, S, d))
    rope = lambda t, p: t
    cache = init_attn_cache(B, buf, KV, hd, jnp.float32)
    for t in range(S):
        o_win, cache = attn_decode(params, cache, x[:, t:t + 1], jnp.int32(t),
                                   n_heads=H, n_kv=KV, head_dim=hd,
                                   rope_fn=rope)
    # reference: full attention restricted to last `buf` positions
    pos = jnp.arange(S)
    full = attn_forward(params, x, n_heads=H, n_kv=KV, head_dim=hd,
                        rope_fn=rope, q_positions=pos, window=buf, chunk=S)
    np.testing.assert_allclose(np.asarray(o_win[:, 0]),
                               np.asarray(full[:, -1]), atol=1e-4)


def test_mqa_single_kv_head():
    q, k, v = _qkv(jax.random.PRNGKey(3), KV=1)
    pos = jnp.arange(64)
    out = chunked_attention(q, k, v, q_positions=pos, k_positions=pos, chunk=32)
    assert out.shape == q.shape and bool(jnp.isfinite(out).all())
