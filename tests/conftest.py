import os

# tests must see exactly ONE device (the dry-run forces 512 in its own
# process); make sure nothing leaks in from the environment.
os.environ.pop("XLA_FLAGS", None)
os.environ.setdefault("JAX_PLATFORMS", "cpu")
