import jax
import jax.numpy as jnp

from repro.core.smoothing import estimate_smoothness, smoothed_loss


def rough_loss(params, batch):
    # |w| has unbounded curvature at 0 -> huge empirical l_s; smoothing fixes it
    return jnp.sum(jnp.abs(params["w"])) + 0.0 * jnp.sum(batch["x"])


def test_smoothed_landscape_is_smoother():
    """Theorem 1: L~ = E_delta L(w + delta) has a smaller gradient-Lipschitz
    constant than L (2G/sigma for G-Lipschitz L)."""
    params = {"w": jnp.full((32,), 0.01)}
    batch = {"x": jnp.zeros((1,))}
    key = jax.random.PRNGKey(0)
    ls_raw = estimate_smoothness(rough_loss, params, batch, key, sigma=0.0,
                                 n_pairs=6, probe_radius=0.02)
    ls_smooth = estimate_smoothness(rough_loss, params, batch, key, sigma=0.3,
                                    n_pairs=6, probe_radius=0.02, n_mc=32)
    assert float(ls_smooth) < float(ls_raw)


def test_smoothed_loss_above_min_for_convex():
    # Jensen: for convex L, L~(w) >= L(w)
    params = {"w": jnp.zeros((16,))}
    batch = {"x": jnp.zeros((1,))}
    l0 = rough_loss(params, batch)
    l1 = smoothed_loss(rough_loss, params, batch, jax.random.PRNGKey(1),
                       sigma=0.1, n_samples=64)
    assert float(l1) > float(l0)
