import jax
import jax.numpy as jnp
import numpy as np

from repro.core.smoothing import estimate_smoothness, smoothed_loss
from repro.landscape import hvp


def rough_loss(params, batch):
    # |w| has unbounded curvature at 0 -> huge empirical l_s; smoothing fixes it
    return jnp.sum(jnp.abs(params["w"])) + 0.0 * jnp.sum(batch["x"])


def test_smoothed_landscape_is_smoother():
    """Theorem 1: L~ = E_delta L(w + delta) has a smaller gradient-Lipschitz
    constant than L (2G/sigma for G-Lipschitz L)."""
    params = {"w": jnp.full((32,), 0.01)}
    batch = {"x": jnp.zeros((1,))}
    key = jax.random.PRNGKey(0)
    ls_raw = estimate_smoothness(rough_loss, params, batch, key, sigma=0.0,
                                 n_pairs=6, probe_radius=0.02)
    ls_smooth = estimate_smoothness(rough_loss, params, batch, key, sigma=0.3,
                                    n_pairs=6, probe_radius=0.02, n_mc=32)
    assert float(ls_smooth) < float(ls_raw)


def test_smoothness_pins_quadratic_lipschitz():
    """For L = 0.5 lam ||w||^2 the gradient map is exactly lam-Lipschitz:
    ||g(x) - g(y)|| / ||x - y|| == lam for EVERY probe pair, so the (now
    vmapped) estimator must return lam to float precision.  The same
    quadratic doubles as the HVP cross-check: H v == lam v."""
    lam = 3.7
    params = {"w": jnp.ones((24,))}
    batch = {"x": jnp.zeros((1,))}

    def quad(p, b):
        return 0.5 * lam * jnp.sum(p["w"] ** 2) + 0.0 * jnp.sum(b["x"])

    ls = estimate_smoothness(quad, params, batch, jax.random.PRNGKey(2),
                             sigma=0.0, n_pairs=8, probe_radius=0.1)
    np.testing.assert_allclose(float(ls), lam, rtol=1e-4)

    # HVP cross-check fixture: the curvature the probe engine would measure
    v = {"w": jnp.linspace(-1.0, 1.0, 24)}
    hv = hvp(lambda p: quad(p, batch), params, v)
    np.testing.assert_allclose(np.asarray(hv["w"]), lam * np.asarray(v["w"]),
                               rtol=1e-5)


def test_smoothed_loss_above_min_for_convex():
    # Jensen: for convex L, L~(w) >= L(w)
    params = {"w": jnp.zeros((16,))}
    batch = {"x": jnp.zeros((1,))}
    l0 = rough_loss(params, batch)
    l1 = smoothed_loss(rough_loss, params, batch, jax.random.PRNGKey(1),
                       sigma=0.1, n_samples=64)
    assert float(l1) > float(l0)
