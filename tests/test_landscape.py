"""Landscape probe engine + closed-loop AutoLR (DESIGN §10).

Pinned against a quadratic with a KNOWN (rotated, non-diagonal) Hessian:
  * Lanczos top eigenvalue and Hutchinson Tr(H) within 5% of analytic,
  * Tr(H C) exact against the explicit covariance contraction,
  * Pallas and ref reorthogonalization bitwise-close,
  * Eq. 4 predictor algebra,
and the headline closed-loop scenario: at alpha * lambda_max = 2.4 (beyond
the stability edge) SSGD diverges while SSGD+AutoLR converges to a loss
threshold — on BOTH the vmap research trainer and the launch/train.py
(pjit) path.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import AlgoConfig, MultiLearnerTrainer
from repro.kernels import ref, reorth_pass, reorthogonalize
from repro.landscape import (AutoLRController, ProbeSchedule,
                             hutchinson_trace, lanczos_pytree,
                             make_trainer_probe, predict_alpha_e,
                             probe_landscape, sharpness, trace_hc)
from repro.optim import (controller_scale, scale_by_controller,
                         set_controller_scale, sgd)

# ---------------------------------------------------------------------------
# the analytic fixture: L(w) = 0.5 w^T A w, A = Q diag(lam) Q^T
# ---------------------------------------------------------------------------

D = 16
LAM = jnp.concatenate([jnp.linspace(1.0, 10.0, D - 1), jnp.array([25.0])])
_Q, _ = jnp.linalg.qr(jax.random.normal(jax.random.PRNGKey(7), (D, D)))
A = _Q @ jnp.diag(LAM) @ _Q.T


def quad_loss(params, batch):
    w = params["w"]
    return 0.5 * w @ A @ w + 0.0 * jnp.sum(batch["x"])


def make_batch(n, b=2):
    return {"x": jnp.zeros((n, b, 1))}


# ---------------------------------------------------------------------------
# estimator accuracy (acceptance: within 5% of analytic)
# ---------------------------------------------------------------------------

def test_lanczos_top_eigenvalue_within_5pct():
    params = {"w": jnp.ones((D,))}
    r = lanczos_pytree(quad_loss, params, make_batch(1), m=10,
                       key=jax.random.PRNGKey(0))
    top = float(sharpness(r))
    assert abs(top - 25.0) / 25.0 < 0.05
    # with full reorthogonalization the whole Ritz spectrum stays inside
    # the true spectral interval (no spurious copies outside [min, max])
    assert float(r.eigenvalues[0]) > 0.5
    assert float(r.eigenvalues[-1]) < 25.0 * 1.05


def test_hutchinson_trace_within_5pct():
    params = {"w": jnp.ones((D,))}
    tr = float(hutchinson_trace(quad_loss, params, make_batch(1),
                                jax.random.PRNGKey(1), n_samples=64))
    true = float(jnp.sum(LAM))
    assert abs(tr - true) / true < 0.05


def test_trace_hc_exact_against_explicit_contraction():
    n = 4
    ws = jax.random.normal(jax.random.PRNGKey(2), (n, D)) * 0.3
    got = float(trace_hc(quad_loss, {"w": ws}, make_batch(n)))
    dev = ws - jnp.mean(ws, axis=0, keepdims=True)
    want = float(jnp.mean(jax.vmap(lambda v: v @ A @ v)(dev)))
    np.testing.assert_allclose(got, want, rtol=1e-5)


def test_probe_landscape_bundle_and_predictor():
    n = 4
    ws = jax.random.normal(jax.random.PRNGKey(3), (n, D)) * 0.2
    r = probe_landscape(quad_loss, {"w": ws}, make_batch(n),
                        jax.random.PRNGKey(4), alpha=0.05, lanczos_iters=10,
                        hutchinson_samples=32)
    assert abs(float(r.sharpness) - 25.0) / 25.0 < 0.05
    sig = float(jnp.sum(jnp.var(ws, axis=0)))
    np.testing.assert_allclose(float(r.sigma_w_sq), sig, rtol=1e-5)
    # Eq. 4: alpha_e_pred == alpha (1 - alpha/2 * TrHC / sigma_w^2)
    want = 0.05 * (1.0 - 0.025 * float(r.trace_hc) / sig)
    np.testing.assert_allclose(float(r.alpha_e_pred), want, rtol=1e-5)
    # identical learners: spread terms vanish, prediction collapses to alpha
    same = {"w": jnp.broadcast_to(ws[0], (n, D))}
    r0 = probe_landscape(quad_loss, same, make_batch(n), jax.random.PRNGKey(4),
                         alpha=0.05, lanczos_iters=8, hutchinson_samples=4)
    np.testing.assert_allclose(float(r0.alpha_e_pred), 0.05, rtol=1e-6)
    assert float(predict_alpha_e(0.1, 0.0, 0.0)) == pytest.approx(0.1)


# ---------------------------------------------------------------------------
# Pallas vs ref reorthogonalization (acceptance: bitwise-close)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("T,M,live", [(2, 4, 4), (512, 6, 3), (331, 8, 8)])
def test_reorth_pallas_vs_ref(T, M, live):
    """One CGS sweep through the fused kernels == the jnp oracle, including
    masking of the dead basis suffix and non-block-multiple row counts."""
    key = jax.random.PRNGKey(T + M)
    basis_raw = jax.random.normal(key, (M, T * 128))
    q, _ = jnp.linalg.qr(basis_raw.T)
    basis = q.T.reshape(M, T, 128)
    w = jax.random.normal(jax.random.fold_in(key, 1), (T, 128))
    mask = (jnp.arange(M) < live).astype(jnp.float32)

    w_k, d_k = reorth_pass(basis, w, mask, interpret=True)
    w_r, d_r = ref.reorth_ref(basis, w, mask)
    np.testing.assert_allclose(np.asarray(d_k), np.asarray(d_r),
                               rtol=1e-6, atol=1e-5)
    np.testing.assert_allclose(np.asarray(w_k), np.asarray(w_r), atol=1e-5)

    # CGS2 wrapper: output is orthogonal to the live basis prefix
    w2 = reorthogonalize(basis, w, mask)
    resid = jnp.einsum("mtl,tl->m", basis, w2) * mask
    assert float(jnp.max(jnp.abs(resid))) < 1e-4 * float(jnp.linalg.norm(w2))


# ---------------------------------------------------------------------------
# schedule / controller / optimizer-adapter units
# ---------------------------------------------------------------------------

def test_probe_schedule_due():
    s = ProbeSchedule(every=10, start=20)
    assert [i for i in range(45) if s.due(i)] == [20, 30, 40]
    assert not any(ProbeSchedule(every=0).due(i) for i in range(5))


def test_autolr_controller_clamps_and_releases():
    ctl = AutoLRController(alpha0=0.1, rho=1.8, min_scale=0.05, ema=0.0)

    def probe_with(sharp):
        z = jnp.zeros(())
        from repro.landscape import ProbeResult
        return ProbeResult(jnp.float32(sharp), z, z, z, z, z, z)

    assert ctl.update(probe_with(180.0)) == pytest.approx(0.1)   # 1.8/(0.1*180)
    assert ctl.update(probe_with(1e6)) == 0.05                   # min clamp
    assert ctl.update(probe_with(1.0)) == 1.0                    # max clamp
    assert ctl.update(probe_with(0.0)) == 1.0                    # flat: release


def test_scale_by_controller_adapter():
    opt = scale_by_controller(sgd(1.0))
    params = {"w": jnp.ones((4,))}
    state = opt.init(params)
    grads = {"w": jnp.ones((4,))}
    upd, state = opt.update(grads, state, params)
    np.testing.assert_allclose(np.asarray(upd["w"]), -1.0)
    state = set_controller_scale(state, 0.25)
    assert float(controller_scale(state)) == 0.25
    upd, state = opt.update(grads, state, params)
    np.testing.assert_allclose(np.asarray(upd["w"]), -0.25)
    # survives apply and a stacked (vmapped) state
    stacked = jax.vmap(opt.init)({"w": jnp.ones((3, 4))})
    stacked = set_controller_scale(stacked, 0.5)
    assert stacked["scale"].shape == (3,)
    # composes in either wrap order: the setter finds the controller layer
    # through outer wrappers (scale_by_schedule adds an "inner" level)
    from repro.optim import constant_schedule, scale_by_schedule
    nested = scale_by_schedule(scale_by_controller(sgd(1.0)),
                               constant_schedule(2.0))
    st = set_controller_scale(nested.init(params), 0.3)
    assert float(controller_scale(st)) == pytest.approx(0.3)
    upd, _ = nested.update(grads, st, params)
    np.testing.assert_allclose(np.asarray(upd["w"]), -0.6)  # 2.0 * 0.3 * -1


# ---------------------------------------------------------------------------
# the headline scenario: SSGD diverges, SSGD+AutoLR converges
# ---------------------------------------------------------------------------

ALPHA = 0.096          # alpha * lambda_max = 2.4 > 2: SSGD diverges
N_STEPS = 120


def _mean_loss(w_stacked):
    w = jnp.mean(w_stacked, axis=0)
    return float(0.5 * w @ A @ w)


def test_ssgd_autolr_beats_ssgd_on_vmap_trainer():
    n = 2
    batch = make_batch(n)
    init = {"w": jnp.ones((D,))}
    loss0 = _mean_loss(jnp.broadcast_to(init["w"], (n, D)))

    # plain SSGD at alpha: the lambda_max mode grows by |1 - 2.4| per step
    tr = MultiLearnerTrainer(quad_loss, sgd(ALPHA),
                             AlgoConfig(algo="ssgd", n_learners=n))
    st = tr.init(jax.random.PRNGKey(0), init)
    for _ in range(60):
        st, m = tr.train_step(st, batch)
    diverged = _mean_loss(st.params["w"])
    assert not np.isfinite(diverged) or diverged > 1e4 * loss0

    # SSGD+AutoLR: probe-driven clamp pulls alpha*lambda inside the edge
    ctl = AutoLRController(alpha0=ALPHA)
    tr2 = MultiLearnerTrainer(quad_loss, scale_by_controller(sgd(ALPHA)),
                              AlgoConfig(algo="ssgd", n_learners=n))
    probe_fn = make_trainer_probe(quad_loss, alpha=ALPHA, lanczos_iters=10,
                                  hutchinson_samples=4)

    def on_probe(state, r):
        return state._replace(opt_state=set_controller_scale(
            state.opt_state, ctl.update(r)))

    tr2.add_probe("landscape", ProbeSchedule(every=10), probe_fn,
                  on_result=on_probe)
    st2 = tr2.init(jax.random.PRNGKey(0), init)
    for i in range(N_STEPS):
        if tr2.probes_due(i):
            st2, _ = tr2.run_probes(st2, batch, step=i)
        st2, m = tr2.train_step(st2, batch)
    final = _mean_loss(st2.params["w"])
    assert np.isfinite(final) and final < 1e-3 * loss0
    # the controller actually intervened (scale strictly below 1)
    assert ctl.scale < 1.0
    # the controlled effective step sits inside the stability edge
    assert 0.5 < ALPHA * ctl.sharpness_ema * ctl.scale < 2.0


def test_ssgd_autolr_beats_ssgd_on_launch_path():
    """Same scenario through the pjit/shard_map production path: the
    launch/train.py SSGD step + the sharded probe entry point
    (make_probe_step, stacked=False) + the controller closing the loop
    through set_controller_scale."""
    from types import SimpleNamespace

    from repro.launch.train import (PjitTrainState, jit_train_step,
                                    make_probe_step, make_ssgd_train_step)

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    api = SimpleNamespace(loss_fn=quad_loss)
    batch = {"x": jnp.zeros((2, 1))}        # (GB, ...) with L=1 learner
    init = {"w": jnp.ones((D,))}
    loss0 = float(0.5 * init["w"] @ A @ init["w"])

    def run(optimizer, with_autolr, steps):
        step_fn = jit_train_step(make_ssgd_train_step(api, optimizer, mesh))
        probe_fn = jax.jit(make_probe_step(api, mesh, alpha=ALPHA,
                                           stacked=False, lanczos_iters=10,
                                           hutchinson_samples=4))
        ctl = AutoLRController(alpha0=ALPHA)
        # the jitted step donates its state: give each run its own buffers
        init_run = jax.tree_util.tree_map(jnp.copy, init)
        state = PjitTrainState(params=init_run,
                               opt_state=optimizer.init(init_run),
                               step=jnp.zeros((), jnp.int32),
                               rng=jax.random.PRNGKey(0))
        with mesh:
            for i in range(steps):
                if with_autolr and i % 10 == 0:
                    r = probe_fn(state.params, batch,
                                 jax.random.fold_in(jax.random.PRNGKey(5), i))
                    state = state._replace(opt_state=set_controller_scale(
                        state.opt_state, ctl.update(r)))
                state, metrics = step_fn(state, batch)
        w = state.params["w"]
        return float(0.5 * w @ A @ w), ctl

    diverged, _ = run(sgd(ALPHA), with_autolr=False, steps=60)
    assert not np.isfinite(diverged) or diverged > 1e4 * loss0

    final, ctl = run(scale_by_controller(sgd(ALPHA)), with_autolr=True,
                     steps=N_STEPS)
    assert np.isfinite(final) and final < 1e-3 * loss0
    assert ctl.scale < 1.0
