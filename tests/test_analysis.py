"""Tier-1 tests for the static invariant auditor (DESIGN §16).

Every registered rule gets a positive case (a seeded violation it must
flag) and a negative case (a clean program it must pass) — the same
contract ``repro.analysis.run --selftest`` enforces at lint time, pinned
here at unit granularity so a broken rule fails the suite, not just the
lint gate.
"""
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import RULES, Finding, format_findings, load_all_rules
from repro.analysis.jaxpr_audit import (aliased_param_bytes,
                                        collective_count, count_primitive,
                                        donation_honored, max_concat_elems,
                                        no_host_callback, no_param_concat,
                                        wire_dtype)
from repro.analysis.lint import (design_refs, kernel_oracle, lint_root,
                                 no_host_sync, no_id_cache)
from repro.analysis.retrace import (RetraceError, RetraceSentinel,
                                    compile_count, no_retrace)
from repro.analysis.run import REPO_ROOT, main
from repro.core.flatstate import max_concat_elems as flatstate_delegate

FIXTURE = REPO_ROOT / "tests" / "fixtures" / "lint_violations"


# ---------------------------------------------------------------------------
# framework
# ---------------------------------------------------------------------------

def test_rule_catalog_complete():
    rules = load_all_rules()
    assert len(rules) >= 8
    for name in ("no-param-concat", "no-host-callback", "collective-count",
                 "wire-dtype", "donation-honored", "no-retrace",
                 "no-host-sync", "no-id-cache", "kernel-oracle",
                 "design-refs"):
        assert name in rules, name
        assert rules[name]            # every rule carries a contract line


def test_duplicate_rule_name_raises():
    from repro.analysis.report import rule

    @rule("dup-test-rule", "contract A")
    def a():
        return []

    with pytest.raises(ValueError):
        @rule("dup-test-rule", "contract B")
        def b():
            return []
    # idempotent re-registration (same contract) is fine: re-imports happen
    @rule("dup-test-rule", "contract A")
    def c():
        return []
    RULES.pop("dup-test-rule")


def test_format_findings():
    f = Finding("some-rule", "file.py:3", "boom")
    assert str(f) == "file.py:3: [some-rule] boom"
    out = format_findings([f, f])
    assert out.endswith("2 finding(s)")


# ---------------------------------------------------------------------------
# jaxpr traversal + max_concat_elems edge cases
# ---------------------------------------------------------------------------

def test_max_concat_empty_jaxpr_is_zero():
    ident = jax.make_jaxpr(lambda x: x)(1.0)
    assert ident.jaxpr.eqns == []
    assert max_concat_elems(ident) == 0


def test_max_concat_accepts_open_and_closed_jaxpr():
    closed = jax.make_jaxpr(
        lambda a, b: jnp.concatenate([a, b]))(jnp.ones(3), jnp.ones(4))
    assert max_concat_elems(closed) == 7
    assert max_concat_elems(closed.jaxpr) == 7          # bare Jaxpr too
    assert flatstate_delegate(closed) == 7              # old import path


def test_max_concat_recurses_into_nested_closed_call():
    j = jax.make_jaxpr(lambda a, b: jax.jit(
        lambda u, v: jnp.concatenate([u, v]))(a, b))(
            jnp.ones(600), jnp.ones(600))
    assert max_concat_elems(j) == 1200


def test_max_concat_recurses_into_scan_body():
    def body(c, x):
        return c, jnp.concatenate([x, x])
    j = jax.make_jaxpr(
        lambda xs: jax.lax.scan(body, 0.0, xs))(jnp.ones((3, 50)))
    assert max_concat_elems(j) == 100


# ---------------------------------------------------------------------------
# rule: no-param-concat
# ---------------------------------------------------------------------------

def test_no_param_concat_flags_big_concat():
    j = jax.make_jaxpr(
        lambda a, b: jnp.concatenate([a, b]))(jnp.ones(600), jnp.ones(600))
    fs = no_param_concat(j, bound=1000, target="toy")
    assert len(fs) == 1 and fs[0].rule == "no-param-concat"
    assert "1200" in fs[0].message


def test_no_param_concat_passes_below_bound():
    j = jax.make_jaxpr(
        lambda a, b: jnp.concatenate([a, b]))(jnp.ones(3), jnp.ones(4))
    assert no_param_concat(j, bound=1000, target="toy") == []


# ---------------------------------------------------------------------------
# rule: no-host-callback
# ---------------------------------------------------------------------------

def test_no_host_callback_flags_pure_callback():
    j = jax.make_jaxpr(lambda x: jax.pure_callback(
        lambda v: v, jax.ShapeDtypeStruct((), jnp.float32), x))(1.0)
    fs = no_host_callback(j, target="toy")
    assert fs and fs[0].rule == "no-host-callback"
    assert "pure_callback" in fs[0].message


def test_no_host_callback_passes_pure_math():
    j = jax.make_jaxpr(lambda x: jnp.sin(x) + 1)(1.0)
    assert no_host_callback(j, target="toy") == []


# ---------------------------------------------------------------------------
# rules: collective-count + wire-dtype (ppermute via a 1-device pmap)
# ---------------------------------------------------------------------------

def _ppermute_jaxpr(dtype=jnp.float32):
    return jax.make_jaxpr(jax.pmap(
        lambda x: jax.lax.ppermute(x, "i", [(0, 0)]),
        axis_name="i"))(jnp.ones((1, 4), dtype))


def test_collective_count_jaxpr_path():
    j = _ppermute_jaxpr()
    assert count_primitive(j, "ppermute") == 1
    assert collective_count(j, expected=1, target="toy") == []
    too_few = collective_count(j, expected=2, target="toy")
    too_many = collective_count(j, expected=0, target="toy")
    assert too_few and too_many            # both directions are violations
    assert too_few[0].rule == "collective-count"


def test_collective_count_hlo_path():
    hlo = ("x = collective-permute(a), source_target_pairs={{0,1}}\n"
           "y = collective-permute-start(b)\n")
    assert collective_count(None, expected=2, target="t",
                            hlo_text=hlo) == []
    fs = collective_count(None, expected=1, target="t", hlo_text=hlo)
    assert fs and "compiled HLO" in fs[0].message


def test_wire_dtype_rule():
    j = _ppermute_jaxpr(jnp.float32)
    assert wire_dtype(j, expected=jnp.float32, target="toy") == []
    fs = wire_dtype(j, expected=jnp.bfloat16, target="toy")
    assert fs and fs[0].rule == "wire-dtype"
    assert "float32" in fs[0].message and "bfloat16" in fs[0].message


# ---------------------------------------------------------------------------
# rule: donation-honored (needs a real compiled executable)
# ---------------------------------------------------------------------------

def test_donation_honored_positive_and_negative():
    x = jnp.ones(1000, jnp.float32)
    donated = jax.jit(lambda v: v + 1, donate_argnums=0).lower(x).compile()
    assert aliased_param_bytes(donated) >= 4000
    assert donation_honored(donated, min_bytes=4000, target="toy") == []

    plain = jax.jit(lambda v: v + 1).lower(x).compile()
    assert aliased_param_bytes(plain) == 0
    fs = donation_honored(plain, min_bytes=4000, target="toy")
    assert fs and fs[0].rule == "donation-honored"
    assert "double-buffered" in fs[0].message


def test_aliased_param_bytes_parser_on_synthetic_hlo():
    hlo = textwrap.dedent("""\
        HloModule toy, input_output_alias={ {0}: (0, {}, may-alias),
        {1}: (2, {}, may-alias) },
        entry_computation_layout={(f32[100,2]{1,0}, s32[7]{0},
        bf16[8,8]{1,0})->(f32[100,2]{1,0}, bf16[8,8]{1,0})}
        """)
    # params 0 (f32[100,2] = 800 B) and 2 (bf16[8,8] = 128 B) are aliased

    class FakeCompiled:
        def as_text(self):
            return hlo

    assert aliased_param_bytes(FakeCompiled()) == 800 + 128


def test_aliased_param_bytes_no_alias_section():
    class FakeCompiled:
        def as_text(self):
            return "HloModule toy\nENTRY main { ROOT r = f32[] const }"

    assert aliased_param_bytes(FakeCompiled()) == 0


# ---------------------------------------------------------------------------
# rule: no-retrace
# ---------------------------------------------------------------------------

def test_sentinel_clean_window():
    f = jax.jit(lambda x: x * 2)
    f(jnp.ones(3))
    with RetraceSentinel(f, strict=True) as s:
        f(jnp.ones(3) + 5)                 # same shape: operand change only
    assert s.findings == []


def test_sentinel_catches_retrace_strict():
    f = jax.jit(lambda x: x * 2)
    f(jnp.ones(3))
    with pytest.raises(RetraceError):
        with RetraceSentinel(f):
            f(jnp.ones(4))                 # new shape: a real retrace


def test_sentinel_collect_mode_and_labels():
    f = jax.jit(lambda x: x * 2)
    f(jnp.ones(3))
    with RetraceSentinel(f, strict=False, labels=["hot-step"]) as s:
        f(jnp.ones((2, 2)))
    assert len(s.findings) == 1
    assert s.findings[0].rule == "no-retrace"
    assert s.findings[0].where == "hot-step"


def test_sentinel_does_not_mask_exceptions():
    f = jax.jit(lambda x: x * 2)
    f(jnp.ones(3))
    with pytest.raises(RuntimeError, match="real failure"):
        with RetraceSentinel(f):
            f(jnp.ones(4))                 # grows the cache, AND ...
            raise RuntimeError("real failure")


def test_sentinel_rejects_unjitted_and_bad_labels():
    with pytest.raises(TypeError):
        compile_count(lambda x: x)
    f = jax.jit(lambda x: x)
    with pytest.raises(ValueError):
        RetraceSentinel(f, labels=["a", "b"])
    with pytest.raises(ValueError):
        RetraceSentinel()


def test_no_retrace_rule_wrapper():
    f = jax.jit(lambda x: x * 2)
    f(jnp.ones(3))
    assert no_retrace(lambda: f(jnp.ones(3)), f) == []
    fs = no_retrace(lambda: f(jnp.ones(5)), f)
    assert fs and fs[0].rule == "no-retrace"


def test_compile_count_unwraps_serve_jitted():
    def raw(x):
        return x + 1
    raw._serve_jitted = jax.jit(raw)
    raw._serve_jitted(jnp.ones(2))
    assert compile_count(raw) == 1


# ---------------------------------------------------------------------------
# rule: no-host-sync (AST)
# ---------------------------------------------------------------------------

HOT_BAD = textwrap.dedent("""\
    import numpy as np
    def step(state, logits):
        a = np.asarray(logits)
        b = state.loss.item()
        logits.block_until_ready()
        return a, b
    """)

HOT_SUPPRESSED = textwrap.dedent("""\
    import numpy as np
    def step(state):
        return np.asarray(state.clock)   # lint: allow-host-sync
    """)

HOT_CLEAN = textwrap.dedent("""\
    import jax.numpy as jnp
    def step(x):
        y = jnp.asarray(x)               # jnp.asarray never syncs
        return x.item(0)                 # .item(i) is numpy indexing
    """)


def test_no_host_sync_flags_all_three_forms():
    fs = no_host_sync(Path("hot.py"), HOT_BAD)
    assert len(fs) == 3
    assert {f.rule for f in fs} == {"no-host-sync"}
    msgs = " ".join(f.message for f in fs)
    assert "asarray" in msgs and "item" in msgs and "block_until_ready" in msgs


def test_no_host_sync_honors_suppression():
    assert no_host_sync(Path("hot.py"), HOT_SUPPRESSED) == []


def test_no_host_sync_ignores_jnp_and_indexed_item():
    assert no_host_sync(Path("hot.py"), HOT_CLEAN) == []


def test_no_host_sync_multiline_statement_suppression():
    src = ("import numpy as np\n"
           "def f(x):\n"
           "    return np.asarray(\n"
           "        x)   # lint: allow-host-sync\n")
    assert no_host_sync(Path("hot.py"), src) == []


# ---------------------------------------------------------------------------
# rule: no-id-cache (AST)
# ---------------------------------------------------------------------------

def test_no_id_cache_flags_subscript_and_get():
    src = ("_C = {}\n"
           "def jitted(fn):\n"
           "    if _C.get(id(fn)) is None:\n"
           "        _C[id(fn)] = fn\n"
           "    return _C[id(fn)]\n")
    fs = no_id_cache(Path("c.py"), src)
    assert len(fs) == 3
    assert {f.rule for f in fs} == {"no-id-cache"}


def test_no_id_cache_passes_attribute_keyed_cache():
    src = ("def jitted(fn):\n"
           "    if getattr(fn, '_j', None) is None:\n"
           "        fn._j = fn\n"
           "    return fn._j\n")
    assert no_id_cache(Path("c.py"), src) == []


# ---------------------------------------------------------------------------
# rule: kernel-oracle (AST)
# ---------------------------------------------------------------------------

def _make_kernels(tmp_path, ref_src, ops_src, modules):
    d = tmp_path / "kernels"
    d.mkdir()
    (d / "ref.py").write_text(ref_src)
    (d / "ops.py").write_text(ops_src)
    for m in modules:
        (d / f"{m}.py").write_text("def impl(x):\n    return x\n")
    return d


def test_kernel_oracle_clean_tree(tmp_path):
    d = _make_kernels(tmp_path,
                      "def foo_ref(x):\n    return x\n",
                      "from .foo import impl\n", ["foo"])
    assert kernel_oracle(d) == []


def test_kernel_oracle_flags_orphan(tmp_path):
    d = _make_kernels(tmp_path,
                      "def foo_ref(x):\n    return x\n",
                      "from .foo import impl\n", ["foo", "orphan"])
    fs = kernel_oracle(d)
    assert len(fs) == 2                     # no oracle AND no dispatch
    assert all("orphan" in f.message for f in fs)


def test_kernel_oracle_flags_missing_ref_py(tmp_path):
    d = tmp_path / "kernels"
    d.mkdir()
    (d / "ops.py").write_text("")
    fs = kernel_oracle(d)
    assert any("ref.py" in f.message for f in fs)


# ---------------------------------------------------------------------------
# rule: design-refs (AST)
# ---------------------------------------------------------------------------

def test_design_refs_resolution(tmp_path):
    (tmp_path / "DESIGN.md").write_text("## §1 A section\nbody\n")
    good = tmp_path / "good.py"
    good.write_text("# see DESIGN §1 for the contract\n")
    bad = tmp_path / "bad.py"
    bad.write_text("# see DESIGN.md §9 for nothing\n")
    assert design_refs(tmp_path, files=[good]) == []
    fs = design_refs(tmp_path, files=[bad])
    assert len(fs) == 1 and "§9" in fs[0].message


def test_design_refs_no_design_md(tmp_path):
    f = tmp_path / "x.py"
    f.write_text("# DESIGN §2\n")
    fs = design_refs(tmp_path, files=[f])
    assert len(fs) == 1                     # nothing can resolve


# ---------------------------------------------------------------------------
# the seeded fixture tree + tree scanning
# ---------------------------------------------------------------------------

def test_fixture_tree_fires_every_ast_rule():
    fs = lint_root(FIXTURE)
    fired = {f.rule for f in fs}
    assert fired == {"no-host-sync", "no-id-cache", "kernel-oracle",
                     "design-refs"}
    # the suppressed np.asarray in hot_loop.py must NOT be among them
    sup = [f for f in fs if "clock" in f.message]
    assert sup == []


def test_fixture_dir_is_skipped_in_parent_scans(tmp_path):
    sub = tmp_path / "fixtures" / "bad"
    sub.mkdir(parents=True)
    (sub / "v.py").write_text("_C = {}\ndef f(x):\n    return _C[id(x)]\n")
    assert lint_root(tmp_path) == []        # skipped as part of a tree
    assert lint_root(sub) != []             # scanned when it IS the root


def test_repo_tree_is_clean():
    """The repo's own AST pass: zero un-suppressed findings (the lint
    gate's first stage, pinned as a test so a violation fails tier-1 with
    a readable message rather than only in CI)."""
    fs = lint_root(REPO_ROOT)
    assert fs == [], format_findings(fs)


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def test_cli_fixture_root_exits_nonzero(capsys):
    rc = main(["--root", str(FIXTURE)])
    assert rc == 1
    out = capsys.readouterr().out
    assert "finding(s)" in out


def test_cli_ast_only_clean(capsys):
    assert main(["--ast-only"]) == 0
    assert "AST pass clean" in capsys.readouterr().out


def test_cli_selftest(capsys):
    assert main(["--selftest"]) == 0
    out = capsys.readouterr().out
    assert "rules bite" in out
