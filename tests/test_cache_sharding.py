"""Direct unit tests of the decode-cache sharding rules (the §Perf H3 fix)."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P


@pytest.fixture(scope="module")
def mesh():
    # build an ABSTRACT mesh over the single CPU device set: sharding-rule
    # logic only reads shape/axis names
    devs = jax.devices()
    if len(devs) < 1:
        pytest.skip("no devices")
    # fake 16x16 by reusing the same device — fine for spec construction only
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}
    return FakeMesh()


def _shard(shapes, mesh):
    from repro.launch.sharding import cache_sharding
    return cache_sharding(shapes, mesh)


def test_attn_cache_time_sharded(mesh):
    cache = {"k": jax.ShapeDtypeStruct((44, 128, 32768, 8, 128), jnp.bfloat16),
             "v": jax.ShapeDtypeStruct((44, 128, 32768, 8, 128), jnp.bfloat16),
             "slot_pos": jax.ShapeDtypeStruct((44, 32768), jnp.int32)}
    s = _shard(cache, mesh)
    # H3: TIME dim over model, batch over data, slot_pos replicated
    assert s["k"] == P(None, ("data",), "model", None, None)
    assert s["v"] == P(None, ("data",), "model", None, None)
    assert s["slot_pos"] == P(None, None)


def test_mamba_state_feature_sharded(mesh):
    cache = {"h": jax.ShapeDtypeStruct((4, 128, 8192, 16), jnp.float32),
             "conv": jax.ShapeDtypeStruct((4, 128, 3, 8192), jnp.bfloat16)}
    s = _shard(cache, mesh)
    assert s["h"] == P(None, ("data",), "model", None)
    assert s["conv"] == P(None, ("data",), None, "model")


def test_batch_one_replicates(mesh):
    cache = {"k": jax.ShapeDtypeStruct((44, 1, 4096, 8, 128), jnp.bfloat16)}
    s = _shard(cache, mesh)
    # batch=1 not divisible by 16 learners -> replicated; window over model
    assert s["k"] == P(None, None, "model", None, None)


def test_cross_attn_cache(mesh):
    cache = {"xk": jax.ShapeDtypeStruct((24, 128, 4096, 16, 64), jnp.bfloat16)}
    s = _shard(cache, mesh)
    assert s["xk"] == P(None, ("data",), "model", None, None)
