"""SPMD gossip-schedule conformance (DESIGN §12).

The launch path derives its collective-permute sequence from the SAME
compiled GossipSchedule tables the fused kernel consumes.  This suite pins,
in an 8-forced-host-device subprocess (own process so the device count does
not leak into the rest of the suite):

  * ppermute gossip == the gather-order reference (bitwise: identical
    accumulation order, f32) for every deterministic schedule, both the
    flat-buffer and per-leaf variants, across a full schedule period;
  * both == the einsum realization of ``schedule.step_matrix`` (allclose —
    the einsum contracts in a different summation order);
  * the compiled HLO issues exactly K x rounds_per_step collective-permutes
    per step (one per non-padded neighbor slot, none for padding).
"""
import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, re
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

try:
    _shard_map = jax.shard_map
except AttributeError:
    from jax.experimental.shard_map import shard_map as _shard_map

from repro.core.dpsgd import (mix_einsum, mix_ppermute_schedule,
                              mix_ppermute_schedule_flat)
from repro.core.schedule import DETERMINISTIC_TOPOLOGIES, make_schedule

n = 8
mesh = jax.make_mesh((n,), ("learners",))
tree = {"a": jax.random.normal(jax.random.PRNGKey(0), (n, 4, 2)),
        "b": jax.random.normal(jax.random.PRNGKey(1), (n, 5))}
specs = jax.tree_util.tree_map(lambda _: P("learners"), tree)


def gather_ref(t, s, step):
    # same accumulation order as _schedule_round_mix: self term, then the
    # neighbor slots in table order, all in f32
    def mix_leaf(x):
        for j in range(s.rounds_per_step):
            r = (step * s.rounds_per_step + j) % s.period
            partners, coefs = s.partners[r], s.coefs[r]
            bshape = (n,) + (1,) * (x.ndim - 1)
            acc = jnp.asarray(coefs[:, 0]).reshape(bshape) * x.astype(
                jnp.float32)
            for k in range(s.K):
                if (partners[k] == np.arange(n)).all() \
                        and not coefs[:, 1 + k].any():
                    continue
                acc = acc + jnp.asarray(coefs[:, 1 + k]).reshape(bshape) \
                    * x[jnp.asarray(partners[k])].astype(jnp.float32)
            x = acc
        return x
    return jax.tree_util.tree_map(mix_leaf, t)


out = {}
for name in DETERMINISTIC_TOPOLOGIES:
    s = make_schedule(name, n)
    res = {"bitwise_flat": True, "bitwise_leaf": True,
           "max_err_vs_einsum": 0.0}
    variants = max(2, s.period if s.time_varying else 1)
    for step in range(variants + 1):        # cross the period boundary too
        st = jnp.int32(step)
        with mesh:
            got_flat = _shard_map(
                lambda p: mix_ppermute_schedule_flat(p, ("learners",), st, s),
                mesh=mesh, in_specs=(specs,), out_specs=specs,
                check_rep=False)(tree)
            got_leaf = _shard_map(
                lambda p: mix_ppermute_schedule(p, ("learners",), st, s),
                mesh=mesh, in_specs=(specs,), out_specs=specs)(tree)
        ref = gather_ref(tree, s, step)
        ein = mix_einsum(tree, s.step_matrix(None, step))
        for k in tree:
            res["bitwise_flat"] &= bool(
                (np.asarray(got_flat[k]) == np.asarray(ref[k])).all())
            res["bitwise_leaf"] &= bool(
                (np.asarray(got_leaf[k]) == np.asarray(ref[k])).all())
            res["max_err_vs_einsum"] = max(
                res["max_err_vs_einsum"],
                float(np.max(np.abs(np.asarray(got_flat[k], np.float64)
                                    - np.asarray(ein[k], np.float64)))))
    # collective count: one permute per non-padded neighbor slot per round
    with mesh:
        lowered = jax.jit(lambda p: _shard_map(
            lambda q: mix_ppermute_schedule_flat(
                q, ("learners",), jnp.int32(0), s),
            mesh=mesh, in_specs=(specs,), out_specs=specs,
            check_rep=False)(p)).lower(tree).compile()
    res["collective_permutes"] = len(re.findall(
        r"collective-permute(?:-start)?\(", lowered.as_text()))
    live_slots = int(sum(
        0 if ((s.partners[r, k] == np.arange(n)).all()
              and not s.coefs[r][:, 1 + k].any()) else 1
        for r in range(s.period) for k in range(s.K)))
    # a static step runs every period round; one_peer_exp runs exactly one
    # round per step but compiles all period branches (lax.switch): XLA
    # keeps one collective per branch, so the count stays == live slots
    res["expected_permutes"] = live_slots
    res["K"] = s.K
    out[name] = res
print(json.dumps(out))
"""


@pytest.fixture(scope="module")
def results():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, env=env, cwd=os.path.dirname(
                           os.path.dirname(os.path.abspath(__file__))),
                       timeout=900)
    assert r.returncode == 0, r.stderr[-3000:]
    return json.loads(r.stdout.strip().splitlines()[-1])


TOPOLOGIES = ("full", "ring", "torus", "hierarchical", "exp", "one_peer_exp")


@pytest.mark.parametrize("name", TOPOLOGIES)
def test_ppermute_matches_gather_reference_bitwise(results, name):
    """Acceptance: the launch ppermute sequence realizes the schedule's
    mixing matrix — bitwise against the identically-ordered gather form,
    for the flat-buffer and per-leaf variants alike."""
    assert results[name]["bitwise_flat"], results[name]
    assert results[name]["bitwise_leaf"], results[name]


@pytest.mark.parametrize("name", TOPOLOGIES)
def test_ppermute_matches_einsum_matrix(results, name):
    """...and against the einsum step-matrix realization up to summation
    order (f32 reassociation only)."""
    assert results[name]["max_err_vs_einsum"] < 1e-6, results[name]


@pytest.mark.parametrize("name", TOPOLOGIES)
def test_collective_count_is_one_permute_per_neighbor_slot(results, name):
    """The flat variant issues exactly one collective-permute per live
    neighbor slot — padding slots cost nothing, and leaf count does not
    multiply the collectives."""
    r = results[name]
    assert r["collective_permutes"] == r["expected_permutes"], r
