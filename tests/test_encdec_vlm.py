"""Enc-dec (seamless) and VLM (qwen2-vl) family-specific behaviour."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import REGISTRY
from repro.models import build_model, make_synthetic_batch
from repro.models.model import _mrope_positions

KEY = jax.random.PRNGKey(0)


def test_encdec_decode_matches_train_forward():
    """Decoder serve_step (KV cache + precomputed cross K/V) reproduces the
    teacher-forced training logits step by step."""
    cfg = REGISTRY["seamless-m4t-large-v2"].smoke_config()
    api = build_model(cfg)
    params = api.init(KEY)
    B, S = 1, 8
    frames = jax.random.normal(KEY, (B, 16, cfg.d_model)) * 0.1
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    full = api.apply(params, {"frames": frames, "tokens": tokens})
    cache = api.init_cache(params, frames, S)
    outs = []
    for t in range(S):
        lg, cache = api.decode_step(params, cache, tokens[:, t:t + 1],
                                    jnp.int32(t))
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(full, np.float32), atol=2e-2)


def test_mrope_positions_structure():
    cfg = REGISTRY["qwen2-vl-7b"].smoke_config()
    P_, S_text = 9, 5
    pos = _mrope_positions(cfg, P_, S_text)
    assert pos.shape == (3, P_ + S_text)
    # image patches: t == 0, (h, w) form a grid
    assert int(pos[0, :P_].max()) == 0
    assert int(pos[1, :P_].max()) == 2 and int(pos[2, :P_].max()) == 2
    # text: all three components equal and strictly increasing
    t = pos[:, P_:]
    assert bool((t[0] == t[1]).all()) and bool((t[0] == t[2]).all())
    assert bool((jnp.diff(t[0]) == 1).all())
    # text positions start after the image grid
    assert int(t[0, 0]) > int(pos[1, :P_].max())


def test_vlm_loss_only_over_text():
    cfg = REGISTRY["qwen2-vl-7b"].smoke_config()
    api = build_model(cfg)
    params = api.init(KEY)
    batch = make_synthetic_batch(cfg, KEY, 2, 32)
    # perturbing patch embeddings changes the loss (they feed the text)
    l1 = float(api.loss_fn(params, batch))
    batch2 = dict(batch)
    batch2["patch_embeds"] = batch["patch_embeds"] + 1.0
    l2 = float(api.loss_fn(params, batch2))
    assert l1 != l2
    logits = api.apply(params, batch)
    assert logits.shape[1] == cfg.n_frontend_tokens + batch["tokens"].shape[1]
