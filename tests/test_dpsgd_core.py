import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dpsgd, topology as topo
from repro.core.util import learner_mean, learner_var, tree_norm_sq, tree_sub


def _tree(key, n):
    k1, k2 = jax.random.split(key)
    return {"a": jax.random.normal(k1, (n, 4, 6)),
            "b": {"c": jax.random.normal(k2, (n, 3))}}


def test_mix_einsum_matches_matrix_math():
    n = 6
    t = _tree(jax.random.PRNGKey(0), n)
    m = topo.ring_matrix(n)
    out = dpsgd.mix_einsum(t, m)
    ref = np.einsum("ij,jkl->ikl", np.asarray(m), np.asarray(t["a"]))
    np.testing.assert_allclose(np.asarray(out["a"]), ref, atol=1e-5)


@pytest.mark.parametrize("topology", ["full", "ring", "random_pair"])
def test_gossip_preserves_mean(topology):
    """Paper Eq. 3: with a doubly stochastic M the average weight is
    untouched by mixing — the learning dynamics of w_a only see gradients."""
    n = 8
    t = _tree(jax.random.PRNGKey(1), n)
    m = topo.make_mixing_fn(topology, n)(jax.random.PRNGKey(2))
    out = dpsgd.mix_einsum(t, m)
    before, after = learner_mean(t), learner_mean(out)
    diff = tree_norm_sq(tree_sub(before, after))
    assert float(diff) < 1e-8


@pytest.mark.parametrize("topology", ["full", "ring", "random_pair"])
def test_gossip_contracts_variance(topology):
    n = 8
    t = _tree(jax.random.PRNGKey(3), n)
    m = topo.make_mixing_fn(topology, n)(jax.random.PRNGKey(4))
    out = dpsgd.mix_einsum(t, m)
    assert float(learner_var(out)) < float(learner_var(t))


def test_full_topology_collapses_spread():
    n = 8
    t = _tree(jax.random.PRNGKey(5), n)
    out = dpsgd.mix_einsum(t, topo.full_matrix(n))
    assert float(learner_var(out)) < 1e-10


def test_perturb_weights_statistics():
    t = {"w": jnp.zeros((4, 1000))}
    noisy = dpsgd.perturb_weights(jax.random.PRNGKey(0), t, std=0.1)
    s = float(jnp.std(noisy["w"]))
    assert 0.08 < s < 0.12


def test_mean_broadcast():
    t = _tree(jax.random.PRNGKey(6), 5)
    out = dpsgd.mean_broadcast(t)
    # the contract is bitwise-identical copies (variance is then ~0 up to
    # the float error of the variance reduction itself)
    for leaf in jax.tree_util.tree_leaves(out):
        for k in range(1, leaf.shape[0]):
            np.testing.assert_array_equal(np.asarray(leaf[0]),
                                          np.asarray(leaf[k]))
    assert float(learner_var(out)) < 1e-12
