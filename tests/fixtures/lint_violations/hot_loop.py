# lint: hot-path
"""Seeded no-host-sync violations: three un-annotated syncs and one
correctly suppressed sync (which must NOT be flagged)."""
import numpy as np


def bad_sync_loop(logits, state):
    lg = np.asarray(logits)                      # violation: np.asarray
    s = state.loss.item()                        # violation: .item()
    logits.block_until_ready()                   # violation: full sync
    ok = np.asarray(state.clock)                 # lint: allow-host-sync
    return lg, s, ok
