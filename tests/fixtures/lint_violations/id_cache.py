"""Seeded no-id-cache violations: the PR 7 serve-cache bug in miniature."""

_CACHE = {}


def cached_compile(fn, compile_fn):
    key = id(fn)
    if _CACHE.get(id(fn)) is None:               # violation: .get(id(...))
        _CACHE[key] = compile_fn(fn)
    return _CACHE[id(fn)]                        # violation: [id(...)]
