"""Oracles for this fixture's kernels — deliberately missing one."""


def good_kernel_ref(x):
    return x
