"""Seeded kernel-oracle violation: no *orphan_kernel*_ref oracle exists in
ref.py and ops.py never imports this module."""


def orphan_kernel_fwd(x):
    return x * 2
