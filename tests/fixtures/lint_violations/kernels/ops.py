"""Dispatcher that forgot one kernel module."""
from .good_kernel import good_kernel_fwd


def good_kernel(x):
    return good_kernel_fwd(x)
