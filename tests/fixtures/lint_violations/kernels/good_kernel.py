def good_kernel_fwd(x):
    return x
