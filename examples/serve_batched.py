"""Batched serving example — now a thin CLI over the serve engine
(repro.serve.ServeEngine): continuous batching with the paged KV cache
instead of a hand-rolled loop on the rotating decode path.

Mixed-length prompts are submitted up front; the engine prefills them
token-at-a-time inside the same fused decode step (no separate prefill
trace), recycles slots as requests finish, and every generated token is
counted — including the first, which the old example dropped.  Timing
starts after ``warmup()`` (compile excluded) and each step host-syncs on
the logits, so the tok/s figure is honest wall-clock.

    PYTHONPATH=src python examples/serve_batched.py --arch gemma2-27b
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serve import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="transformer-100m")
    ap.add_argument("--batch", type=int, default=4, help="decode slots")
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--buf", type=int, default=64,
                    help="max tokens per request (prompt + generated)")
    ap.add_argument("--page", type=int, default=8, help="KV page size")
    ap.add_argument("--requests", type=int, default=0,
                    help="requests to serve (default: 2x slots)")
    args = ap.parse_args()

    cfg = get_config(args.arch).smoke_config()
    api = build_model(cfg)
    if not api.has_paged:
        raise SystemExit(f"{cfg.name}: family {cfg.family} has no paged "
                         "decode path (text families only)")
    params = api.init(jax.random.PRNGKey(0))

    eng = ServeEngine(api, params, n_slots=args.batch, page_size=args.page,
                      max_len=args.buf)
    rng = np.random.default_rng(0)
    n_req = args.requests or 2 * args.batch
    max_prompt = max(1, args.buf - args.new_tokens)
    reqs = [eng.submit(rng.integers(1, cfg.vocab,
                                    rng.integers(1, max_prompt + 1)).tolist(),
                       args.new_tokens)
            for _ in range(n_req)]

    eng.warmup()                      # compile outside the timed region
    t0 = time.perf_counter()
    eng.run()
    dt = time.perf_counter() - t0

    total = eng.generated_total
    print(f"arch={cfg.name} slots={args.batch} page={args.page} "
          f"buf={args.buf} requests={n_req}")
    print(f"{dt * 1e3 / eng.real_steps:.1f} ms/step  "
          f"({total / dt:.1f} tok/s aggregate, {total} tokens, "
          f"{eng.real_steps} steps)")
    print("sequences:")
    for r in reqs[:4]:
        print("  ", r.generated[:16], "...")


if __name__ == "__main__":
    main()
