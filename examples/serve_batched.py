"""Batched serving example: greedy decoding with the rotating-KV-cache
decode path (the same serve_step the dry-run lowers for decode_32k /
long_500k, here on the reduced config at CPU scale).

    PYTHONPATH=src python examples/serve_batched.py --arch gemma2-27b
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="transformer-100m")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--buf", type=int, default=64)
    args = ap.parse_args()

    cfg = get_config(args.arch).smoke_config()
    api = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = api.init(key)
    if cfg.family == "audio":
        frames = jax.random.normal(key, (args.batch, 16, cfg.d_model)) * 0.1
        cache = api.init_cache(params, frames, args.buf)
    else:
        cache = api.init_cache(params, args.batch, args.buf)

    decode = jax.jit(api.decode_step)
    tokens = jnp.zeros((args.batch, 1), jnp.int32)
    generated = [tokens]
    logits, cache = decode(params, cache, tokens, jnp.int32(0))  # compile
    t0 = time.time()
    for pos in range(1, args.new_tokens):
        tokens = jnp.argmax(logits[..., :cfg.vocab], axis=-1).astype(jnp.int32)
        generated.append(tokens)
        logits, cache = decode(params, cache, tokens, jnp.int32(pos))
    dt = (time.time() - t0) / (args.new_tokens - 1)
    out = jnp.concatenate(generated, axis=1)
    print(f"arch={cfg.name} batch={args.batch} buf={args.buf}")
    print(f"{dt * 1e3:.1f} ms/token/batch  "
          f"({args.batch / dt:.1f} tok/s aggregate)")
    print("sequences:")
    for row in out[:4]:
        print("  ", row.tolist()[:16], "...")


if __name__ == "__main__":
    main()
