"""Quickstart: train the paper's FC net with DPSGD vs SSGD at a large
learning rate in the large-batch setting (the paper's headline experiment).

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.core import AlgoConfig, MultiLearnerTrainer
from repro.data import ShardedLoader, TemplateImages
from repro.models import fcnet
from repro.optim import sgd

LR, N_LEARNERS, LOCAL_BATCH, STEPS = 0.5, 5, 400, 120


def train(algo: str, *, lr: float = LR, n_learners: int = N_LEARNERS,
          local_batch: int = LOCAL_BATCH, steps: int = STEPS,
          log_every: int = 20):
    loader = ShardedLoader(TemplateImages(), n_learners=n_learners,
                           local_batch=local_batch, seed=0)
    key = jax.random.PRNGKey(0)
    trainer = MultiLearnerTrainer(
        fcnet.loss_fn, sgd(lr),
        AlgoConfig(algo=algo, topology="random_pair", n_learners=n_learners))
    state = trainer.init(key, fcnet.init_params(key, in_dim=784, hidden=50))
    for step in range(steps):
        state, metrics = trainer.train_step(state, loader.batch(step))
        if step % log_every == 0:
            print(f"  [{algo}] step {step:4d} loss {float(metrics.loss):.4f} "
                  f"sigma_w^2 {float(metrics.sigma_w_sq):.2e}")
    return float(metrics.loss)


def main(*, steps: int = STEPS, local_batch: int = LOCAL_BATCH):
    print(f"large batch (nB={N_LEARNERS * local_batch}), lr={LR}")
    ssgd = train("ssgd", steps=steps, local_batch=local_batch)
    dpsgd = train("dpsgd", steps=steps, local_batch=local_batch)
    print(f"\nfinal loss: SSGD={ssgd:.4f}  DPSGD={dpsgd:.4f} "
          f"-> {'DPSGD converges where SSGD fails (paper Fig. 2a)' if dpsgd < ssgd else 'unexpected'}")
    return ssgd, dpsgd


if __name__ == "__main__":
    main()
