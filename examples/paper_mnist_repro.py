"""Paper Fig. 2 reproduction (MNIST stand-in, see DESIGN.md §9):
n=5 learners, 784-50-50-10 FC net, nB=2000, large lr.

Produces results/bench/paper_fig2_repro.csv with the loss / alpha_e /
sigma_w^2 / Delta_S / Delta2 trajectories for SSGD, SSGD*, DPSGD.

    PYTHONPATH=src python examples/paper_mnist_repro.py
"""
import csv
import os

import jax

from repro.core import AlgoConfig, MultiLearnerTrainer
from repro.data import ShardedLoader, TemplateImages
from repro.models import fcnet
from repro.optim import sgd

LR, STEPS = 0.5, 150
OUT = os.path.join(os.path.dirname(__file__), "..", "results", "bench",
                   "paper_fig2_repro.csv")


def run(algo):
    loader = ShardedLoader(TemplateImages(), n_learners=5, local_batch=400,
                           seed=0)
    key = jax.random.PRNGKey(0)
    tr = MultiLearnerTrainer(
        fcnet.loss_fn, sgd(LR),
        AlgoConfig(algo=algo, topology="random_pair", n_learners=5,
                   noise_std=0.01),
        alpha_for_diag=LR)
    st = tr.init(key, fcnet.init_params(key, in_dim=784, hidden=50))
    rows = []
    for i in range(STEPS):
        st, m = tr.train_step(st, loader.batch(i))
        if i % 10 == 0:
            d = tr.diagnostics(st, loader.batch(10_000 + i))
            acc_batch = loader.eval_batch(512)
            acc = float(jax.jit(fcnet.accuracy)(
                jax.tree_util.tree_map(lambda x: x.mean(0),
                                       tr.params_tree(st)),
                acc_batch))
            rows.append([algo, i, float(m.loss), float(d.alpha_e),
                         float(d.sigma_w_sq), float(d.delta_s),
                         float(d.delta_2), acc])
            print(f"[{algo}] step {i:4d} loss {float(m.loss):7.4f} "
                  f"alpha_e {float(d.alpha_e):6.3f} "
                  f"sigma_w2 {float(d.sigma_w_sq):8.2e} test_acc {acc:.3f}")
    return rows


if __name__ == "__main__":
    os.makedirs(os.path.dirname(OUT), exist_ok=True)
    allrows = []
    for algo in ("ssgd", "ssgd_star", "dpsgd"):
        print(f"=== {algo} (lr={LR}, nB=2000) ===")
        allrows += run(algo)
    with open(OUT, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["algo", "step", "loss", "alpha_e", "sigma_w_sq",
                    "delta_s", "delta_2", "test_acc"])
        w.writerows(allrows)
    print(f"\nwrote {OUT}")
