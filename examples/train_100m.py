"""End-to-end training driver (deliverable b): ~100M-parameter dense LM
trained with DPSGD on the synthetic token pipeline, with checkpointing and
heldout eval.  Full production-shape run:

    PYTHONPATH=src python examples/train_100m.py --steps 300 --seq 512

CPU-friendly demo (default): reduced seq/batch, same 100M architecture.
CI smoke: --preset smoke uses the reduced config.
"""
import argparse
import os
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.configs import get_config
from repro.core import AlgoConfig, MultiLearnerTrainer
from repro.data import ShardedLoader, SyntheticTokenStream
from repro.models import build_model
from repro.optim import sgd, scale_by_schedule, warmup_linear_scale


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--local-batch", type=int, default=2)
    ap.add_argument("--learners", type=int, default=4)
    ap.add_argument("--lr", type=float, default=0.5)
    ap.add_argument("--preset", choices=["full", "smoke"], default="full")
    ap.add_argument("--ckpt-dir", default="results/ckpt_100m")
    ap.add_argument("--ckpt-every", type=int, default=20)
    args = ap.parse_args()

    cfg = get_config("transformer-100m")
    if args.preset == "smoke":
        cfg = cfg.smoke_config()
    api = build_model(cfg)
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(
        jax.eval_shape(api.init, jax.random.PRNGKey(0))))
    print(f"model: {cfg.name}  params={n_params / 1e6:.1f}M  "
          f"learners={args.learners}  nB={args.learners * args.local_batch}")

    ds = SyntheticTokenStream(vocab=cfg.vocab)
    loader = ShardedLoader(ds, n_learners=args.learners,
                           local_batch=args.local_batch,
                           extra_args=(args.seq,))
    # paper recipe: warmup + linear scaling, DPSGD random-neighbor gossip
    opt = scale_by_schedule(sgd(args.lr, momentum=0.9),
                            warmup_linear_scale(10, 1.0))
    trainer = MultiLearnerTrainer(
        api.loss_fn, opt,
        AlgoConfig(algo="dpsgd", topology="random_pair",
                   n_learners=args.learners))
    key = jax.random.PRNGKey(0)
    state = trainer.init(key, api.init(key))

    def ckpt_tree(st):
        # checkpoint the pytree VIEW so checkpoints stay layout-stable
        # across trainer engines (the flat engine stores (n, T, 128))
        v = trainer.state_view(st)
        return {"params": v.params, "opt": v.opt_state}

    if latest_step(args.ckpt_dir) is not None:
        tree, step0 = restore_checkpoint(args.ckpt_dir, ckpt_tree(state))
        state = trainer.state_from_view(state._replace(
            params=tree["params"], opt_state=tree["opt"]))
        state = state._replace(step=jnp.int32(step0))
        print(f"resumed from step {step0}")

    t0 = time.time()
    for i in range(int(state.step), args.steps):
        state, m = trainer.train_step(state, loader.batch(i))
        if i % 5 == 0 or i == args.steps - 1:
            dt = (time.time() - t0) / max(i - int(state.step) + 1, 1)
            print(f"step {i:4d}  loss {float(m.loss):.4f}  "
                  f"sigma_w^2 {float(m.sigma_w_sq):.2e}  {dt:.1f}s/step")
        if args.ckpt_every and i and i % args.ckpt_every == 0:
            save_checkpoint(args.ckpt_dir, i, ckpt_tree(state))
    heldout = float(trainer.eval_loss(state, loader.eval_batch(8)))
    print(f"heldout loss: {heldout:.4f}")
    save_checkpoint(args.ckpt_dir, args.steps, ckpt_tree(state))
    print(f"checkpoint saved to {args.ckpt_dir}")


if __name__ == "__main__":
    main()
