PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))
export JAX_PLATFORMS ?= cpu

.PHONY: test lint bench-smoke bench bench-check dryrun

# tier-1 suite (the repo's verify command)
test:
	$(PYTHON) -m pytest -x -q

# static invariant auditor (DESIGN §16): selftest proves every rule still
# bites on its seeded violation, then the AST pass + jaxpr/retrace audits
# run over the repo itself (trainer, launch step, serve decode).  Any
# un-suppressed finding is exit 1.  ruff is a style extra: config lives in
# pyproject.toml, but the binary isn't baked into every container, so the
# pass is gated on availability (CI installs it; the auditor always runs).
lint:
	$(PYTHON) -m repro.analysis.run --selftest
	$(PYTHON) -m repro.analysis.run
	@if command -v ruff >/dev/null 2>&1; then \
	    ruff check src tests benchmarks; \
	else \
	    echo "ruff not installed — style pass skipped (auditor ran)"; \
	fi

# quick benchmark subset: one dynamics figure, the kernel microbench, the
# straggler measurement (the async path), the engine regression harness
# (flat vs pytree, BENCH_PR3.json), the GossipSchedule topology sweep, the
# serving engine (continuous vs static batching + consensus bridge), the
# fault-injection harness (elastic membership: crash/rejoin under the
# Supervisor) and the benchmark matrix (smoke mode: trimmed axes, short
# training, emits BENCH_PR8.json)
bench-smoke:
	$(PYTHON) -m benchmarks.fig2_effective_lr
	$(PYTHON) -m benchmarks.bench_kernels
	$(PYTHON) -m benchmarks.fig3_straggler
	$(PYTHON) -m benchmarks.bench_throughput
	$(PYTHON) -m benchmarks.ablation_topology --smoke
	$(PYTHON) -m benchmarks.serving --smoke
	$(PYTHON) -m benchmarks.faults --smoke
	$(PYTHON) -m benchmarks.matrix --smoke

# bench-smoke + the CSV output contract (benchmarks/README.md): every
# benchmark prints `name,us_per_call,derived` and writes a results table
# capture with a redirect (not a pipe) so a failing benchmark fails the
# target even without pipefail in the default make shell; clear the tables
# first — the gate vouches only for THIS run's output, never stale CSVs.
# check_regression gates BOTH the legacy flat-vs-pytree parity band
# (BENCH_PR3.json) and the cross-PR per-cell trajectory over every
# BENCH_PR<N>.json this run emitted; trajectory writes the cross-PR report
bench-check:
	rm -rf results/bench
	$(MAKE) bench-smoke > bench_smoke.out 2>&1; status=$$?; \
	    cat bench_smoke.out; exit $$status
	$(PYTHON) -m benchmarks.check_contract bench_smoke.out \
	    fig2_effective_lr bench_kernel fig3_straggler bench_throughput \
	    ablation_topology bench_serving bench_faults bench_matrix
	$(PYTHON) -m benchmarks.check_regression "results/bench/BENCH_PR*.json"
	$(PYTHON) -m benchmarks.trajectory

# the full paper sweep (writes results/bench/*.csv)
bench:
	$(PYTHON) -m benchmarks.run

# 512-host-device lowering sweep (no weights allocated)
dryrun:
	$(PYTHON) -m repro.launch.dryrun --all --mesh single
