PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))
export JAX_PLATFORMS ?= cpu

.PHONY: test bench-smoke bench dryrun

# tier-1 suite (the repo's verify command)
test:
	$(PYTHON) -m pytest -x -q

# quick benchmark subset: one dynamics figure, the kernel microbench and the
# straggler measurement (the new async path)
bench-smoke:
	$(PYTHON) -m benchmarks.fig2_effective_lr
	$(PYTHON) -m benchmarks.bench_kernels
	$(PYTHON) -m benchmarks.fig3_straggler

# the full paper sweep (writes results/bench/*.csv)
bench:
	$(PYTHON) -m benchmarks.run

# 512-host-device lowering sweep (no weights allocated)
dryrun:
	$(PYTHON) -m repro.launch.dryrun --all --mesh single
